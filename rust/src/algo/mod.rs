//! Per-node **algorithms** and assignments (paper §3.1).
//!
//! "For a given node of a computation graph, there exist one or more
//! implementations that can perform the computation of the operator. We
//! call each implementation an *algorithm* of the node." — exactly cuDNN's
//! multiple convolution kernels. Our concrete algorithm set:
//!
//! | Op | Algorithms | cuDNN analogue |
//! |---|---|---|
//! | Conv2d | `ConvIm2col` (A), `ConvDirect` (B), `ConvWinograd` (C, 3×3 s1 only), `Conv1x1Gemm` (1×1 only) | GEMM / IMPLICIT_GEMM / WINOGRAD / 1x1 specialization |
//! | MatMul | `GemmBlocked`, `GemmNaive` | cuBLAS algo selection |
//! | everything else | `Passthrough` | single-kernel ops |
//!
//! Applicability constraints mirror the paper's footnote 2: "Some cuDNN
//! algorithms are not applicable to all convolution operators" (Table 1
//! shows `-` for Winograd on conv1/conv2).

use crate::energysim::FreqId;
use crate::graph::{Graph, NodeId, OpKind, TensorShape};

/// An implementation choice for one node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Algorithm {
    /// im2col + blocked GEMM: highest arithmetic throughput, extra memory
    /// traffic (the unfolded patch matrix) — paper's "algorithm A" profile:
    /// fast but power-hungry.
    ConvIm2col,
    /// Direct sliding window: no workspace, lower bandwidth pressure —
    /// "algorithm B": often a bit slower but much lower power.
    ConvDirect,
    /// Winograd F(2×2,3×3): 2.25× multiply reduction — "algorithm C":
    /// fastest *and* cheapest where applicable (3×3, stride 1).
    ConvWinograd,
    /// Pointwise 1×1 convolution as a pure GEMM.
    Conv1x1Gemm,
    /// Depthwise convolution, direct sliding window.
    DwDirect,
    /// Depthwise convolution, per-channel Winograd F(2×2,3×3).
    DwWinograd,
    /// Cache-blocked GEMM.
    GemmBlocked,
    /// Naive triple-loop GEMM.
    GemmNaive,
    /// The single implementation of ops that have only one.
    Passthrough,
}

impl Algorithm {
    /// Number of distinct algorithm variants — the bound of
    /// [`Algorithm::ordinal`], used to size dense per-algorithm lookup
    /// tables (the cost table's indexed slabs).
    pub const COUNT: usize = 9;

    /// Dense ordinal of the variant in declaration order (`0..COUNT`) —
    /// the key of the cost table's O(1) algorithm→option index.
    pub fn ordinal(&self) -> usize {
        match self {
            Algorithm::ConvIm2col => 0,
            Algorithm::ConvDirect => 1,
            Algorithm::ConvWinograd => 2,
            Algorithm::Conv1x1Gemm => 3,
            Algorithm::DwDirect => 4,
            Algorithm::DwWinograd => 5,
            Algorithm::GemmBlocked => 6,
            Algorithm::GemmNaive => 7,
            Algorithm::Passthrough => 8,
        }
    }

    /// Stable serialization name (plan files, profile DB keys).
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::ConvIm2col => "im2col",
            Algorithm::ConvDirect => "direct",
            Algorithm::ConvWinograd => "winograd",
            Algorithm::Conv1x1Gemm => "1x1gemm",
            Algorithm::DwDirect => "dw_direct",
            Algorithm::DwWinograd => "dw_winograd",
            Algorithm::GemmBlocked => "gemm_blocked",
            Algorithm::GemmNaive => "gemm_naive",
            Algorithm::Passthrough => "std",
        }
    }

    /// Paper Table 1 letter for conv algorithms (reporting only).
    pub fn letter(&self) -> &'static str {
        match self {
            Algorithm::ConvIm2col => "A",
            Algorithm::ConvDirect => "B",
            Algorithm::ConvWinograd => "C",
            Algorithm::Conv1x1Gemm => "D",
            _ => "-",
        }
    }

    /// Inverse of [`Algorithm::name`].
    pub fn from_name(name: &str) -> Option<Algorithm> {
        Some(match name {
            "im2col" => Algorithm::ConvIm2col,
            "direct" => Algorithm::ConvDirect,
            "winograd" => Algorithm::ConvWinograd,
            "1x1gemm" => Algorithm::Conv1x1Gemm,
            "dw_direct" => Algorithm::DwDirect,
            "dw_winograd" => Algorithm::DwWinograd,
            "gemm_blocked" => Algorithm::GemmBlocked,
            "gemm_naive" => Algorithm::GemmNaive,
            "std" => Algorithm::Passthrough,
            _ => return None,
        })
    }
}

/// The registry answering "which algorithms can run this node?" (the paper
/// assumes "a method of knowing all algorithms of N" — provided by the
/// engine/underlying library; this is that method).
#[derive(Debug, Clone, Default)]
pub struct AlgorithmRegistry;

impl AlgorithmRegistry {
    /// The (stateless) registry.
    pub fn new() -> Self {
        AlgorithmRegistry
    }

    /// All algorithms applicable to a node with the given op and input
    /// shapes, in preference order (first = framework default).
    pub fn applicable(&self, op: &OpKind, in_shapes: &[TensorShape]) -> Vec<Algorithm> {
        match op {
            OpKind::Conv2d { stride, .. } => {
                let w = &in_shapes[1];
                let (r, s) = (w[2], w[3]);
                let mut algos = vec![Algorithm::ConvIm2col, Algorithm::ConvDirect];
                if r == 3 && s == 3 && *stride == (1, 1) {
                    algos.push(Algorithm::ConvWinograd);
                }
                if r == 1 && s == 1 {
                    algos.push(Algorithm::Conv1x1Gemm);
                }
                algos
            }
            OpKind::DwConv2d { stride, .. } => {
                let w = &in_shapes[1];
                let mut algos = vec![Algorithm::DwDirect];
                if (w[2], w[3]) == (3, 3) && *stride == (1, 1) {
                    algos.push(Algorithm::DwWinograd);
                }
                algos
            }
            OpKind::MatMul { .. } => vec![Algorithm::GemmBlocked, Algorithm::GemmNaive],
            _ => vec![Algorithm::Passthrough],
        }
    }

    /// The framework-default algorithm (what "Origin" and "MetaFlow Best
    /// Time" run with — no per-node tuning).
    pub fn default_algorithm(&self, op: &OpKind, in_shapes: &[TensorShape]) -> Algorithm {
        self.applicable(op, in_shapes)[0]
    }
}

/// An algorithm assignment `A` for a graph: maps every runtime node to an
/// algorithm (paper §3.1). Constant-space nodes (weights & folds) carry
/// `None`. With DVFS enabled the plan also carries a per-node frequency
/// state; `FreqId::NOMINAL` everywhere is the pre-DVFS plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Assignment {
    choices: Vec<Option<Algorithm>>,
    freqs: Vec<FreqId>,
}

impl Assignment {
    /// Assemble an assignment from raw parts — the cost oracle's delta
    /// evaluation path builds candidate defaults by carrying unchanged
    /// choices over from the parent plan instead of re-deriving them.
    pub(crate) fn from_parts(choices: Vec<Option<Algorithm>>, freqs: Vec<FreqId>) -> Assignment {
        Assignment { choices, freqs }
    }

    /// The default assignment for a graph.
    pub fn default_for(g: &Graph, reg: &AlgorithmRegistry) -> Assignment {
        let shapes = g.infer_shapes().expect("assignment over invalid graph");
        Assignment::default_for_with(g, &shapes, reg)
    }

    /// As [`Assignment::default_for`] but with pre-computed shapes — the
    /// search hot path infers shapes once per candidate and reuses them.
    pub fn default_for_with(
        g: &Graph,
        shapes: &[Vec<TensorShape>],
        reg: &AlgorithmRegistry,
    ) -> Assignment {
        let mut choices = vec![None; g.len()];
        for (id, node) in g.nodes() {
            if node.op.is_constant_space() {
                continue;
            }
            let in_shapes: Vec<TensorShape> = node
                .inputs
                .iter()
                .map(|p| shapes[p.node.0][p.port].clone())
                .collect();
            choices[id.0] = Some(reg.default_algorithm(&node.op, &in_shapes));
        }
        let freqs = vec![FreqId::NOMINAL; g.len()];
        Assignment { choices, freqs }
    }

    /// The algorithm assigned to a node (`None` for constant-space nodes).
    pub fn get(&self, id: NodeId) -> Option<Algorithm> {
        self.choices.get(id.0).copied().flatten()
    }

    /// Assign a node's algorithm. Panics on constant-space nodes.
    pub fn set(&mut self, id: NodeId, algo: Algorithm) {
        assert!(self.choices[id.0].is_some(), "cannot assign to constant-space node");
        self.choices[id.0] = Some(algo);
    }

    /// The DVFS state a node runs at (`NOMINAL` unless a DVFS search or a
    /// loaded plan set one).
    pub fn freq(&self, id: NodeId) -> FreqId {
        self.freqs.get(id.0).copied().unwrap_or(FreqId::NOMINAL)
    }

    /// Set a node's DVFS state. Panics on constant-space nodes.
    pub fn set_freq(&mut self, id: NodeId, freq: FreqId) {
        assert!(self.choices[id.0].is_some(), "cannot set frequency on constant-space node");
        self.freqs[id.0] = freq;
    }

    /// The device a node is placed on. Placement rides on the packed
    /// frequency state, so the default (`NOMINAL`) is the GPU and every
    /// pre-placement plan is all-GPU by construction.
    pub fn device(&self, id: NodeId) -> crate::energysim::DeviceId {
        self.freq(id).device()
    }

    /// The distinct devices runtime nodes are placed on, ascending — one
    /// entry (`GPU`) for every pre-placement plan.
    pub fn devices_used(&self) -> Vec<crate::energysim::DeviceId> {
        let mut out: Vec<crate::energysim::DeviceId> =
            self.assigned_ids().map(|id| self.device(id)).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Whether any runtime node is placed off the GPU — the gate for the
    /// manifest v4 device keys and the serve-side provider check.
    pub fn uses_non_gpu_device(&self) -> bool {
        self.assigned_ids().any(|id| self.device(id) != crate::energysim::DeviceId::GPU)
    }

    /// The tensor layout a node computes in. Layout rides on the packed
    /// frequency state like the device, so the default (`NOMINAL`) is NCHW
    /// and every pre-layout plan is all-NCHW by construction.
    pub fn layout(&self, id: NodeId) -> crate::energysim::Layout {
        self.freq(id).layout()
    }

    /// The distinct layouts runtime nodes compute in, ascending — one
    /// entry (`NCHW`) for every pre-layout plan.
    pub fn layouts_used(&self) -> Vec<crate::energysim::Layout> {
        let mut out: Vec<crate::energysim::Layout> =
            self.assigned_ids().map(|id| self.layout(id)).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Whether any runtime node computes in a non-default layout — the
    /// gate for the manifest v5 layout keys.
    pub fn uses_non_default_layout(&self) -> bool {
        self.assigned_ids().any(|id| self.layout(id) != crate::energysim::Layout::NCHW)
    }

    /// Pin every runtime node to one DVFS state (`--dvfs per-graph` plans).
    pub fn set_uniform_freq(&mut self, freq: FreqId) {
        for i in 0..self.choices.len() {
            if self.choices[i].is_some() {
                self.freqs[i] = freq;
            }
        }
    }

    /// The single frequency every runtime node runs at, or `NOMINAL` when
    /// the plan mixes states (per-node DVFS).
    pub fn uniform_freq(&self) -> FreqId {
        let mut uniform: Option<FreqId> = None;
        for id in self.assigned_ids() {
            let f = self.freq(id);
            match uniform {
                None => uniform = Some(f),
                Some(u) if u != f => return FreqId::NOMINAL,
                _ => {}
            }
        }
        uniform.unwrap_or(FreqId::NOMINAL)
    }

    /// (frequency, node count) over runtime nodes, ascending by clock with
    /// `NOMINAL` last — reporting helper for DVFS plans.
    pub fn freq_histogram(&self) -> Vec<(FreqId, usize)> {
        let mut counts: std::collections::BTreeMap<FreqId, usize> = Default::default();
        for id in self.assigned_ids() {
            *counts.entry(self.freq(id)).or_default() += 1;
        }
        let mut out: Vec<(FreqId, usize)> = counts.into_iter().collect();
        // NOMINAL (0) sorts first by value; move it last for readability.
        if out.first().is_some_and(|(f, _)| f.is_nominal()) {
            out.rotate_left(1);
        }
        out
    }

    /// Total node slots (equals the graph's node count).
    pub fn len(&self) -> usize {
        self.choices.len()
    }

    /// Whether the assignment covers no nodes.
    pub fn is_empty(&self) -> bool {
        self.choices.is_empty()
    }

    /// Ids of nodes that carry an algorithm (runtime nodes).
    pub fn assigned_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.choices
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_some())
            .map(|(i, _)| NodeId(i))
    }

    /// Ids with more than one applicable algorithm — the inner search's
    /// effective dimensions.
    pub fn tunable_ids(&self, g: &Graph, reg: &AlgorithmRegistry) -> Vec<NodeId> {
        let shapes = g.infer_shapes().expect("invalid graph");
        self.assigned_ids()
            .filter(|id| {
                let node = g.node(*id);
                let in_shapes: Vec<TensorShape> = node
                    .inputs
                    .iter()
                    .map(|p| shapes[p.node.0][p.port].clone())
                    .collect();
                reg.applicable(&node.op, &in_shapes).len() > 1
            })
            .collect()
    }

    /// Paper §3.1: `distance(A1, A2)` = number of nodes mapped differently
    /// — with the DVFS axis, a node counts once when its (algorithm,
    /// frequency) pair differs. Only defined over the same graph.
    pub fn distance(&self, other: &Assignment) -> usize {
        assert_eq!(self.choices.len(), other.choices.len(), "assignments over different graphs");
        self.choices
            .iter()
            .zip(&self.freqs)
            .zip(other.choices.iter().zip(&other.freqs))
            .filter(|(a, b)| a != b)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Graph, OpKind, PortRef};

    fn conv_op(stride: (usize, usize)) -> OpKind {
        OpKind::Conv2d {
            stride,
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        }
    }

    #[test]
    fn winograd_applicability_mirrors_table1() {
        let reg = AlgorithmRegistry::new();
        // 3x3 stride 1: A, B, C all applicable (like paper's conv3).
        let a3 = reg.applicable(&conv_op((1, 1)), &[vec![1, 3, 8, 8], vec![4, 3, 3, 3]]);
        assert!(a3.contains(&Algorithm::ConvWinograd));
        // 3x3 stride 2: C not applicable (like conv1/conv2 showing "-").
        let a2 = reg.applicable(&conv_op((2, 2)), &[vec![1, 3, 8, 8], vec![4, 3, 3, 3]]);
        assert!(!a2.contains(&Algorithm::ConvWinograd));
        // 1x1: gets the pointwise GEMM specialization.
        let a1 = reg.applicable(&conv_op((1, 1)), &[vec![1, 3, 8, 8], vec![4, 3, 1, 1]]);
        assert!(a1.contains(&Algorithm::Conv1x1Gemm));
        assert!(!a1.contains(&Algorithm::ConvWinograd));
    }

    #[test]
    fn default_is_first_applicable() {
        let reg = AlgorithmRegistry::new();
        assert_eq!(
            reg.default_algorithm(&conv_op((1, 1)), &[vec![1, 3, 8, 8], vec![4, 3, 3, 3]]),
            Algorithm::ConvIm2col
        );
        assert_eq!(reg.default_algorithm(&OpKind::Relu, &[vec![1, 3, 8, 8]]), Algorithm::Passthrough);
    }

    #[test]
    fn assignment_default_and_distance() {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(conv_op((1, 1)), &[x, w], "c");
        let r = g.add1(OpKind::Relu, &[c], "r");
        g.outputs = vec![PortRef::of(r)];

        let reg = AlgorithmRegistry::new();
        let a0 = Assignment::default_for(&g, &reg);
        assert_eq!(a0.get(c), Some(Algorithm::ConvIm2col));
        assert_eq!(a0.get(w), None); // weights carry no algorithm
        let mut a1 = a0.clone();
        a1.set(c, Algorithm::ConvWinograd);
        assert_eq!(a0.distance(&a1), 1);
        assert_eq!(a0.distance(&a0), 0);
    }

    #[test]
    fn tunable_ids_only_multi_algorithm_nodes() {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(conv_op((1, 1)), &[x, w], "c");
        let r = g.add1(OpKind::Relu, &[c], "r");
        g.outputs = vec![PortRef::of(r)];
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let tunable = a.tunable_ids(&g, &reg);
        assert_eq!(tunable, vec![c]); // relu/input have one algorithm
    }

    #[test]
    #[should_panic(expected = "constant-space")]
    fn cannot_assign_weight_node() {
        let mut g = Graph::new();
        let w = g.add1(OpKind::weight(vec![2, 2], 0), &[], "w");
        let m = g.add1(OpKind::matmul(), &[w, w], "m");
        g.outputs = vec![PortRef::of(m)];
        let reg = AlgorithmRegistry::new();
        let mut a = Assignment::default_for(&g, &reg);
        a.set(w, Algorithm::Passthrough);
    }

    #[test]
    fn assignment_freq_axis_defaults_and_distance() {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(conv_op((1, 1)), &[x, w], "c");
        let r = g.add1(OpKind::Relu, &[c], "r");
        g.outputs = vec![PortRef::of(r)];
        let reg = AlgorithmRegistry::new();
        let a0 = Assignment::default_for(&g, &reg);
        assert_eq!(a0.freq(c), FreqId::NOMINAL);
        assert_eq!(a0.uniform_freq(), FreqId::NOMINAL);

        // Same algorithm, different frequency = distance 1 (the DVFS axis
        // is part of the plan identity).
        let mut a1 = a0.clone();
        a1.set_freq(c, FreqId(900));
        assert_eq!(a0.distance(&a1), 1);
        assert_ne!(a0, a1);
        assert_eq!(a1.uniform_freq(), FreqId::NOMINAL, "mixed plan has no uniform state");

        let mut a2 = a0.clone();
        a2.set_uniform_freq(FreqId(705));
        assert_eq!(a2.uniform_freq(), FreqId(705));
        assert_eq!(a2.freq(w), FreqId::NOMINAL, "weights carry no frequency");
        let hist = a1.freq_histogram();
        assert_eq!(hist.last(), Some(&(FreqId::NOMINAL, a1.assigned_ids().count() - 1)));
        assert!(hist.contains(&(FreqId(900), 1)));
    }

    #[test]
    fn assignment_device_axis_rides_on_freq() {
        use crate::energysim::DeviceId;
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(conv_op((1, 1)), &[x, w], "c");
        let r = g.add1(OpKind::Relu, &[c], "r");
        g.outputs = vec![PortRef::of(r)];
        let reg = AlgorithmRegistry::new();
        let a0 = Assignment::default_for(&g, &reg);
        assert_eq!(a0.device(c), DeviceId::GPU);
        assert_eq!(a0.devices_used(), vec![DeviceId::GPU]);
        assert!(!a0.uses_non_gpu_device());

        let mut a1 = a0.clone();
        a1.set_freq(c, FreqId::on(DeviceId::DLA, 0));
        assert_eq!(a1.device(c), DeviceId::DLA);
        assert_eq!(a1.devices_used(), vec![DeviceId::GPU, DeviceId::DLA]);
        assert!(a1.uses_non_gpu_device());
        // Migration is a plan-identity change like any (algo, freq) move.
        assert_eq!(a0.distance(&a1), 1);
    }

    #[test]
    fn assignment_layout_axis_rides_on_freq() {
        use crate::energysim::Layout;
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w");
        let c = g.add1(conv_op((1, 1)), &[x, w], "c");
        let r = g.add1(OpKind::Relu, &[c], "r");
        g.outputs = vec![PortRef::of(r)];
        let reg = AlgorithmRegistry::new();
        let a0 = Assignment::default_for(&g, &reg);
        assert_eq!(a0.layout(c), Layout::NCHW);
        assert_eq!(a0.layouts_used(), vec![Layout::NCHW]);
        assert!(!a0.uses_non_default_layout());

        let mut a1 = a0.clone();
        a1.set_freq(c, a1.freq(c).with_layout(Layout::NHWC));
        assert_eq!(a1.layout(c), Layout::NHWC);
        assert_eq!(a1.layouts_used(), vec![Layout::NCHW, Layout::NHWC]);
        assert!(a1.uses_non_default_layout());
        // A layout flip is a plan-identity change like any (algo, freq) move.
        assert_eq!(a0.distance(&a1), 1);
        // The device field is untouched by the layout bit.
        assert_eq!(a1.device(c), crate::energysim::DeviceId::GPU);
    }

    #[test]
    fn ordinals_are_dense_and_unique() {
        let all = [
            Algorithm::ConvIm2col,
            Algorithm::ConvDirect,
            Algorithm::ConvWinograd,
            Algorithm::Conv1x1Gemm,
            Algorithm::DwDirect,
            Algorithm::DwWinograd,
            Algorithm::GemmBlocked,
            Algorithm::GemmNaive,
            Algorithm::Passthrough,
        ];
        assert_eq!(all.len(), Algorithm::COUNT);
        let mut seen = [false; Algorithm::COUNT];
        for a in all {
            let o = a.ordinal();
            assert!(o < Algorithm::COUNT);
            assert!(!seen[o], "duplicate ordinal {o}");
            seen[o] = true;
        }
    }

    #[test]
    fn algorithm_name_roundtrip() {
        for a in [
            Algorithm::ConvIm2col,
            Algorithm::ConvDirect,
            Algorithm::ConvWinograd,
            Algorithm::Conv1x1Gemm,
            Algorithm::GemmBlocked,
            Algorithm::GemmNaive,
            Algorithm::Passthrough,
        ] {
            assert_eq!(Algorithm::from_name(a.name()), Some(a));
        }
        assert_eq!(Algorithm::from_name("bogus"), None);
    }
}
