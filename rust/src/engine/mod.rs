//! The inference engine: executes a `(Graph, Assignment)` pair.
//!
//! This substitutes for MetaFlow's built-in engine (the paper runs optimized
//! graphs "on the MetaFlow's built-in inference engine"). Two backends:
//!
//! - [`reference`]: pure-rust execution through [`crate::tensor`], each node
//!   dispatched to its *assigned algorithm* — the semantic ground truth used
//!   to verify substitutions and to time algorithms on the host.
//! - [`pjrt`]: per-node-signature AOT artifacts (JAX/Pallas-lowered HLO)
//!   executed through the PJRT CPU client; falls back to reference for
//!   signatures without an artifact.
//!
//! Weight tensors are realized deterministically from `(seed, kind)` by
//! [`weights::realize`]; weight-space constant ops (BN folds, kernel pads,
//! filter concats) are evaluated once at plan time by the same node
//! executor, so the request path touches only runtime ops.

/// Single-node execution: dispatch an op to its assigned algorithm.
pub mod exec;
/// PJRT-hybrid engine (AOT artifacts with reference fallback).
pub mod pjrt;
/// Pure-rust reference engine (semantic ground truth).
pub mod reference;
/// Deterministic weight realization from `(seed, kind)`.
pub mod weights;

pub use reference::ReferenceEngine;

use crate::tensor::Tensor;

/// Uniform result type for engine runs.
#[derive(Debug)]
pub struct RunOutput {
    /// Graph output tensors, in `graph.outputs` order.
    pub outputs: Vec<Tensor>,
    /// Wallclock of the run (seconds), excluding plan/fold time.
    pub wall_s: f64,
}
