//! Single-node execution: dispatch an operator to its assigned algorithm's
//! implementation. Shared by the reference engine, the substitution
//! equivalence checker, and the CPU profiler.

use crate::algo::Algorithm;
use crate::graph::op::{eps_val, Activation, OpKind};
use crate::tensor::{conv, ops, winograd, Tensor};

/// Execute one node. `inputs` follow the op's port conventions; the result
/// is one tensor per output port.
pub fn execute_node(
    op: &OpKind,
    algo: Algorithm,
    inputs: &[&Tensor],
) -> anyhow::Result<Vec<Tensor>> {
    let one = |t: Tensor| Ok(vec![t]);
    match op {
        OpKind::Input { .. } | OpKind::Weight { .. } => {
            anyhow::bail!("{} nodes are sources, not executable", op.mnemonic())
        }
        OpKind::Conv2d { stride, pad, act, has_bias, has_residual } => {
            let x = inputs[0];
            let w = inputs[1];
            let mut idx = 2;
            let bias = if *has_bias {
                idx += 1;
                Some(inputs[idx - 1])
            } else {
                None
            };
            let residual = has_residual.then(|| inputs[idx]);
            let mut y = match algo {
                Algorithm::ConvDirect => conv::conv2d_direct(x, w, bias, *stride, *pad),
                Algorithm::ConvIm2col => conv::conv2d_im2col(x, w, bias, *stride, *pad),
                Algorithm::ConvWinograd => {
                    let (_, _, r, s) = w.dims4();
                    anyhow::ensure!(
                        winograd::applicable(r, s, *stride),
                        "winograd assigned to inapplicable conv ({r}x{s}, stride {stride:?})"
                    );
                    winograd::conv2d_winograd(x, w, bias, *pad)
                }
                Algorithm::Conv1x1Gemm => {
                    let (_, _, r, s) = w.dims4();
                    anyhow::ensure!(
                        (r, s) == (1, 1) && *pad == (0, 0),
                        "1x1gemm assigned to non-1x1/padded conv"
                    );
                    conv::conv2d_1x1_gemm(x, w, bias, *stride)
                }
                other => anyhow::bail!("algorithm {other:?} not valid for conv2d"),
            };
            if let Some(r) = residual {
                y = ops::add(&y, r);
            }
            if *act == Activation::Relu {
                y = ops::relu(&y);
            }
            one(y)
        }
        OpKind::DwConv2d { stride, pad, act, has_bias } => {
            let x = inputs[0];
            let w = inputs[1];
            let bias = has_bias.then(|| inputs[2]);
            let mut y = match algo {
                Algorithm::DwDirect => {
                    crate::tensor::depthwise::dwconv2d_direct(x, w, bias, *stride, *pad)
                }
                Algorithm::DwWinograd => {
                    let (_, _, r, s) = w.dims4();
                    anyhow::ensure!(
                        r == 3 && s == 3 && *stride == (1, 1),
                        "dw_winograd assigned to inapplicable depthwise conv"
                    );
                    crate::tensor::depthwise::dwconv2d_winograd(x, w, bias, *pad)
                }
                other => anyhow::bail!("algorithm {other:?} not valid for dwconv2d"),
            };
            if *act == Activation::Relu {
                y = ops::relu(&y);
            }
            one(y)
        }
        OpKind::MatMul { act, has_bias } => {
            let mut y = match algo {
                Algorithm::GemmNaive => ops::matmul_naive(inputs[0], inputs[1]),
                Algorithm::GemmBlocked => ops::matmul_blocked(inputs[0], inputs[1]),
                other => anyhow::bail!("algorithm {other:?} not valid for matmul"),
            };
            if *has_bias {
                y = ops::add(&y, inputs[2]);
            }
            if *act == Activation::Relu {
                y = ops::relu(&y);
            }
            one(y)
        }
        OpKind::Relu => one(ops::relu(inputs[0])),
        OpKind::Sigmoid => one(ops::sigmoid(inputs[0])),
        OpKind::Add => one(ops::add(inputs[0], inputs[1])),
        OpKind::AddRelu => one(ops::relu(&ops::add(inputs[0], inputs[1]))),
        OpKind::Mul => one(ops::mul(inputs[0], inputs[1])),
        OpKind::MaxPool { k, stride, pad } => {
            one(ops::maxpool_nchw(inputs[0], k.0, k.1, stride.0, stride.1, pad.0, pad.1))
        }
        OpKind::AvgPool { k, stride, pad } => {
            one(ops::avgpool_nchw(inputs[0], k.0, k.1, stride.0, stride.1, pad.0, pad.1))
        }
        OpKind::GlobalAvgPool => one(ops::global_avgpool_nchw(inputs[0])),
        OpKind::BatchNorm { eps } => one(ops::batchnorm_nchw(
            inputs[0],
            inputs[1],
            inputs[2],
            inputs[3],
            inputs[4],
            eps_val(*eps),
        )),
        OpKind::Concat { axis } => one(ops::concat_axis(inputs, *axis)),
        OpKind::Split { axis, sizes } => Ok(ops::split_axis(inputs[0], *axis, sizes)),
        OpKind::Flatten => one(ops::flatten(inputs[0])),
        OpKind::Softmax => one(ops::softmax_rows(inputs[0])),
        OpKind::FoldBnWeight { eps } => {
            let (w, gamma, var) = (inputs[0], inputs[1], inputs[2]);
            let (k, c, r, s) = w.dims4();
            let mut out = w.clone();
            let e = eps_val(*eps);
            for ki in 0..k {
                let scale = gamma.data()[ki] / (var.data()[ki] + e).sqrt();
                let base = ki * c * r * s;
                for v in &mut out.data_mut()[base..base + c * r * s] {
                    *v *= scale;
                }
            }
            one(out)
        }
        OpKind::FoldBnBias { eps, has_bias } => {
            let (b0, rest) = if *has_bias {
                (Some(inputs[0]), &inputs[1..])
            } else {
                (None, inputs)
            };
            let (gamma, beta, mean, var) = (rest[0], rest[1], rest[2], rest[3]);
            let k = gamma.len();
            let e = eps_val(*eps);
            let mut out = vec![0.0f32; k];
            for (ki, o) in out.iter_mut().enumerate() {
                let scale = gamma.data()[ki] / (var.data()[ki] + e).sqrt();
                let b = b0.map_or(0.0, |t| t.data()[ki]);
                *o = (b - mean.data()[ki]) * scale + beta.data()[ki];
            }
            one(Tensor::new(vec![k], out))
        }
        OpKind::PadKernel { target } => {
            let w = inputs[0];
            let (k, c, r, s) = w.dims4();
            let (tr, ts) = *target;
            let (dr, ds) = ((tr - r) / 2, (ts - s) / 2);
            let mut out = Tensor::zeros(&[k, c, tr, ts]);
            for ki in 0..k {
                for ci in 0..c {
                    for ry in 0..r {
                        for sx in 0..s {
                            *out.at4_mut(ki, ci, ry + dr, sx + ds) = w.at4(ki, ci, ry, sx);
                        }
                    }
                }
            }
            one(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::eps_bits;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn conv_algorithms_agree() {
        let mut rng = Rng::seed_from(44);
        let x = Tensor::rand(&[1, 3, 8, 8], &mut rng, -1.0, 1.0);
        let w = Tensor::rand(&[4, 3, 3, 3], &mut rng, -0.5, 0.5);
        let op = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::Relu,
            has_bias: false,
            has_residual: false,
        };
        let y_direct = execute_node(&op, Algorithm::ConvDirect, &[&x, &w]).unwrap();
        let y_im2col = execute_node(&op, Algorithm::ConvIm2col, &[&x, &w]).unwrap();
        let y_wino = execute_node(&op, Algorithm::ConvWinograd, &[&x, &w]).unwrap();
        assert_close(y_direct[0].data(), y_im2col[0].data(), 1e-4, 1e-4).unwrap();
        assert_close(y_direct[0].data(), y_wino[0].data(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn conv_residual_and_act_applied_in_order() {
        // y = relu(conv(x) + res): check a negative pre-activation is clamped
        let x = Tensor::full(&[1, 1, 2, 2], 1.0);
        let w = Tensor::full(&[1, 1, 1, 1], -1.0);
        let res = Tensor::full(&[1, 1, 2, 2], 0.5);
        let op = OpKind::Conv2d {
            stride: (1, 1),
            pad: (0, 0),
            act: Activation::Relu,
            has_bias: false,
            has_residual: true,
        };
        let y = execute_node(&op, Algorithm::ConvDirect, &[&x, &w, &res]).unwrap();
        // conv = -1, + res = -0.5, relu -> 0
        assert!(y[0].data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn winograd_rejected_when_inapplicable() {
        let x = Tensor::zeros(&[1, 1, 8, 8]);
        let w = Tensor::zeros(&[1, 1, 3, 3]);
        let op = OpKind::Conv2d {
            stride: (2, 2),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        assert!(execute_node(&op, Algorithm::ConvWinograd, &[&x, &w]).is_err());
    }

    #[test]
    fn fold_bn_weight_matches_batchnorm() {
        // conv(x, w') + b' must equal bn(conv(x, w)) — the FuseConvBn rule's
        // semantic core, checked at the op level.
        let mut rng = Rng::seed_from(45);
        let x = Tensor::rand(&[1, 3, 6, 6], &mut rng, -1.0, 1.0);
        let w = Tensor::rand(&[4, 3, 3, 3], &mut rng, -0.5, 0.5);
        let gamma = Tensor::rand(&[4], &mut rng, 0.8, 1.2);
        let beta = Tensor::rand(&[4], &mut rng, -0.1, 0.1);
        let mean = Tensor::rand(&[4], &mut rng, -0.1, 0.1);
        let var = Tensor::rand(&[4], &mut rng, 0.5, 1.5);
        let eps = 1e-5f32;

        let conv_op = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        let y_conv = execute_node(&conv_op, Algorithm::ConvDirect, &[&x, &w]).unwrap();
        let y_bn = ops::batchnorm_nchw(&y_conv[0], &gamma, &beta, &mean, &var, eps);

        let wf = execute_node(
            &OpKind::FoldBnWeight { eps: eps_bits(eps) },
            Algorithm::Passthrough,
            &[&w, &gamma, &var],
        )
        .unwrap();
        let bf = execute_node(
            &OpKind::FoldBnBias { eps: eps_bits(eps), has_bias: false },
            Algorithm::Passthrough,
            &[&gamma, &beta, &mean, &var],
        )
        .unwrap();
        let fold_op = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: true,
            has_residual: false,
        };
        let y_folded =
            execute_node(&fold_op, Algorithm::ConvDirect, &[&x, &wf[0], &bf[0]]).unwrap();
        assert_close(y_bn.data(), y_folded[0].data(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn pad_kernel_preserves_conv_semantics() {
        // conv1x1(x, w) == conv3x3_pad1(x, pad(w))
        let mut rng = Rng::seed_from(46);
        let x = Tensor::rand(&[1, 3, 5, 5], &mut rng, -1.0, 1.0);
        let w = Tensor::rand(&[2, 3, 1, 1], &mut rng, -0.5, 0.5);
        let op1 = OpKind::Conv2d {
            stride: (1, 1),
            pad: (0, 0),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        let y1 = execute_node(&op1, Algorithm::ConvDirect, &[&x, &w]).unwrap();
        let wp = execute_node(
            &OpKind::PadKernel { target: (3, 3) },
            Algorithm::Passthrough,
            &[&w],
        )
        .unwrap();
        let op3 = OpKind::Conv2d {
            stride: (1, 1),
            pad: (1, 1),
            act: Activation::None,
            has_bias: false,
            has_residual: false,
        };
        let y3 = execute_node(&op3, Algorithm::ConvDirect, &[&x, &wp[0]]).unwrap();
        assert_close(y1[0].data(), y3[0].data(), 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn fused_matmul_matches_unfused_chain() {
        // fused matmul+bias+relu == relu(add(matmul(a, b), bias))
        let mut rng = Rng::seed_from(47);
        let a = Tensor::rand(&[3, 5], &mut rng, -1.0, 1.0);
        let b = Tensor::rand(&[5, 4], &mut rng, -1.0, 1.0);
        let bias = Tensor::rand(&[3, 4], &mut rng, -1.0, 1.0);
        let plain = execute_node(&OpKind::matmul(), Algorithm::GemmBlocked, &[&a, &b]).unwrap();
        let expect = ops::relu(&ops::add(&plain[0], &bias));
        let fused = execute_node(
            &OpKind::MatMul { act: Activation::Relu, has_bias: true },
            Algorithm::GemmBlocked,
            &[&a, &b, &bias],
        )
        .unwrap();
        assert_close(expect.data(), fused[0].data(), 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn split_produces_multiple_ports() {
        let x = Tensor::new(vec![1, 4, 1, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let op = OpKind::Split { axis: 1, sizes: vec![1, 3] };
        let outs = execute_node(&op, Algorithm::Passthrough, &[&x]).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].data(), &[1.0]);
        assert_eq!(outs[1].data(), &[2.0, 3.0, 4.0]);
    }

    use crate::tensor::ops;
}
