//! PJRT-backed engine: executes runtime nodes through AOT JAX/Pallas
//! artifacts when one matches the node's `(signature, algorithm)` key, and
//! falls back to the reference implementation otherwise.

use super::exec::execute_node;
use super::reference::ReferenceEngine;
use super::RunOutput;
use crate::algo::{Algorithm, Assignment};
use crate::graph::{Graph, OpKind};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::time::Instant;

/// Execution statistics of a hybrid run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HybridStats {
    /// Nodes executed through a PJRT artifact.
    pub pjrt_nodes: usize,
    /// Nodes executed through the reference fallback.
    pub reference_nodes: usize,
}

/// A prepared hybrid execution plan: weights realized + constants folded
/// (once), per-node artifact keys resolved (once). Serving reuses it across
/// requests — the §Perf serving-path optimization.
pub struct PjrtPlan {
    plan: crate::engine::reference::Plan,
    input_ids: Vec<crate::graph::NodeId>,
    /// Per scheduled node: Some(artifact key) if the runtime has it.
    keys: Vec<Option<String>>,
}

/// Engine dispatching per-node to PJRT artifacts with reference fallback.
pub struct PjrtEngine<'rt> {
    /// The loaded-artifact runtime backing PJRT dispatch.
    pub runtime: &'rt Runtime,
    reference: ReferenceEngine,
}

impl<'rt> PjrtEngine<'rt> {
    /// Build an engine over a (possibly empty) loaded runtime.
    pub fn new(runtime: &'rt Runtime) -> PjrtEngine<'rt> {
        PjrtEngine { runtime, reference: ReferenceEngine::new() }
    }

    /// Artifact key of a node: `<signature>::<algorithm>`.
    pub fn node_key(sig: &str, algo: Algorithm) -> String {
        format!("{sig}::{}", algo.name())
    }

    /// Build a reusable plan: fold constants, resolve artifact keys.
    pub fn prepare(&self, g: &Graph, a: &Assignment) -> anyhow::Result<PjrtPlan> {
        let plan = self.reference.plan(g, a)?;
        let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!(e))?;
        let input_ids: Vec<_> = g
            .nodes()
            .filter(|(_, n)| matches!(n.op, OpKind::Input { .. }))
            .map(|(id, _)| id)
            .collect();
        let keys = plan
            .schedule()
            .iter()
            .map(|id| {
                let node = g.node(*id);
                let in_shapes: Vec<_> = node
                    .inputs
                    .iter()
                    .map(|p| shapes[p.node.0][p.port].clone())
                    .collect();
                let algo = a.get(*id).unwrap_or(Algorithm::Passthrough);
                let key = Self::node_key(&node.op.signature(&in_shapes), algo);
                self.runtime.has(&key).then_some(key)
            })
            .collect();
        Ok(PjrtPlan { plan, input_ids, keys })
    }

    /// Execute a prepared plan on concrete inputs.
    pub fn run_prepared(
        &self,
        g: &Graph,
        a: &Assignment,
        prepared: &PjrtPlan,
        inputs: &[Tensor],
    ) -> anyhow::Result<(RunOutput, HybridStats)> {
        let t0 = Instant::now();
        let mut stats = HybridStats::default();
        let mut values: BTreeMap<(usize, usize), Tensor> = BTreeMap::new();
        anyhow::ensure!(
            inputs.len() == prepared.input_ids.len(),
            "expected {} inputs, got {}",
            prepared.input_ids.len(),
            inputs.len()
        );
        for (id, t) in prepared.input_ids.iter().zip(inputs) {
            values.insert((id.0, 0), t.clone());
        }

        // Weights are realized and the constant subgraph folded in the
        // prepared plan; only the runtime schedule executes here.
        for (slot, id) in prepared.plan.schedule().iter().enumerate() {
            let node = g.node(*id);
            let ins: Vec<&Tensor> = node
                .inputs
                .iter()
                .map(|p| {
                    values
                        .get(&(p.node.0, p.port))
                        .or_else(|| prepared.plan.constant(p.node.0, p.port))
                        .ok_or_else(|| {
                            anyhow::anyhow!("node {} input {:?} unavailable", id.0, p)
                        })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outs = if let Some(key) = &prepared.keys[slot] {
                stats.pjrt_nodes += 1;
                self.runtime.execute(key, &ins)?
            } else {
                let algo = a.get(*id).unwrap_or(Algorithm::Passthrough);
                stats.reference_nodes += 1;
                execute_node(&node.op, algo, &ins)
                    .map_err(|e| anyhow::anyhow!("node {} ({}): {e}", id.0, node.name))?
            };
            for (port, t) in outs.into_iter().enumerate() {
                values.insert((id.0, port), t);
            }
        }

        let outputs = g
            .outputs
            .iter()
            .map(|p| {
                values
                    .get(&(p.node.0, p.port))
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("output {:?} not computed", p))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok((RunOutput { outputs, wall_s: t0.elapsed().as_secs_f64() }, stats))
    }

    /// One-shot convenience: prepare + run.
    pub fn run(
        &self,
        g: &Graph,
        a: &Assignment,
        inputs: &[Tensor],
    ) -> anyhow::Result<(RunOutput, HybridStats)> {
        let prepared = self.prepare(g, a)?;
        self.run_prepared(g, a, &prepared, inputs)
    }
}
