//! Reference engine: executes a `(Graph, Assignment)` through the pure-rust
//! tensor ops, with plan-time constant folding of the weight subgraph.

use super::exec::execute_node;
use super::weights;
use super::RunOutput;
use crate::algo::{Algorithm, Assignment};
use crate::graph::{Graph, NodeId, OpKind};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::time::Instant;

/// An execution plan: constant-folded weights + topological schedule of the
/// runtime nodes. Build once, run many times.
pub struct Plan {
    /// Folded constants by (node, port).
    constants: BTreeMap<(usize, usize), Tensor>,
    /// Runtime schedule (topo order, constant-space nodes excluded).
    schedule: Vec<NodeId>,
    /// Input node ids, in graph order.
    input_ids: Vec<NodeId>,
    /// Reference count of each node's outputs (for memory reclamation).
    uses: Vec<usize>,
}

impl Plan {
    /// Constant-folded tensor at (node, port), if that node was folded.
    pub fn constant(&self, node: usize, port: usize) -> Option<&Tensor> {
        self.constants.get(&(node, port))
    }

    /// Runtime schedule (topo order over non-constant nodes).
    pub fn schedule(&self) -> &[NodeId] {
        &self.schedule
    }
}

/// Pure-rust backend.
#[derive(Debug, Default)]
pub struct ReferenceEngine;

impl ReferenceEngine {
    /// The (stateless) reference engine.
    pub fn new() -> ReferenceEngine {
        ReferenceEngine
    }

    /// Build the execution plan: realize weights, fold the constant
    /// subgraph (BN folds, kernel pads, filter concats), and schedule the
    /// remaining runtime nodes.
    pub fn plan(&self, g: &Graph, _a: &Assignment) -> anyhow::Result<Plan> {
        g.validate().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        let order = g.topo_order().map_err(|e| anyhow::anyhow!(e))?;
        let mut constants: BTreeMap<(usize, usize), Tensor> = BTreeMap::new();
        let mut is_const = vec![false; g.len()];
        let mut schedule = Vec::new();
        let mut input_ids = Vec::new();

        for id in &order {
            let node = g.node(*id);
            match &node.op {
                OpKind::Input { .. } => input_ids.push(*id),
                OpKind::Weight { shape, seed, kind } => {
                    constants.insert((id.0, 0), weights::realize(shape, *seed, *kind));
                    is_const[id.0] = true;
                }
                op => {
                    // A node is constant-foldable iff all inputs are constant.
                    let all_const = node.inputs.iter().all(|p| is_const[p.node.0]);
                    if all_const && op.is_constant_space() {
                        let ins: Vec<&Tensor> = node
                            .inputs
                            .iter()
                            .map(|p| &constants[&(p.node.0, p.port)])
                            .collect();
                        let outs = execute_node(op, Algorithm::Passthrough, &ins)?;
                        for (port, t) in outs.into_iter().enumerate() {
                            constants.insert((id.0, port), t);
                        }
                        is_const[id.0] = true;
                    } else if all_const && matches!(op, OpKind::Concat { .. }) {
                        // Weight-space concat (merging parallel conv filters)
                        // is a runtime op kind used in constant context.
                        let ins: Vec<&Tensor> = node
                            .inputs
                            .iter()
                            .map(|p| &constants[&(p.node.0, p.port)])
                            .collect();
                        let outs = execute_node(op, Algorithm::Passthrough, &ins)?;
                        for (port, t) in outs.into_iter().enumerate() {
                            constants.insert((id.0, port), t);
                        }
                        is_const[id.0] = true;
                    } else {
                        schedule.push(*id);
                    }
                }
            }
        }

        // Output-reference counting for tensor reclamation during runs.
        let mut uses = vec![0usize; g.len()];
        for (_, node) in g.nodes() {
            for p in &node.inputs {
                uses[p.node.0] += 1;
            }
        }
        for out in &g.outputs {
            uses[out.node.0] += usize::MAX / 2; // outputs never reclaimed
        }

        Ok(Plan { constants, schedule, input_ids, uses })
    }

    /// Execute a prepared plan on concrete inputs (one tensor per graph
    /// `Input` node, in id order).
    pub fn run_plan(
        &self,
        g: &Graph,
        a: &Assignment,
        plan: &Plan,
        inputs: &[Tensor],
    ) -> anyhow::Result<RunOutput> {
        anyhow::ensure!(
            inputs.len() == plan.input_ids.len(),
            "expected {} inputs, got {}",
            plan.input_ids.len(),
            inputs.len()
        );
        let t0 = Instant::now();
        let mut values: BTreeMap<(usize, usize), Tensor> = BTreeMap::new();
        let mut remaining: Vec<usize> = plan.uses.clone();
        for (id, t) in plan.input_ids.iter().zip(inputs) {
            let expect = match &g.node(*id).op {
                OpKind::Input { shape } => shape.clone(),
                _ => unreachable!(),
            };
            anyhow::ensure!(
                t.shape() == expect.as_slice(),
                "input {} shape {:?} != declared {:?}",
                id.0,
                t.shape(),
                expect
            );
            values.insert((id.0, 0), t.clone());
        }
        for id in &plan.schedule {
            let node = g.node(*id);
            let ins: Vec<&Tensor> = node
                .inputs
                .iter()
                .map(|p| {
                    values
                        .get(&(p.node.0, p.port))
                        .or_else(|| plan.constants.get(&(p.node.0, p.port)))
                        .expect("scheduled before input ready")
                })
                .collect();
            let algo = a.get(*id).unwrap_or(Algorithm::Passthrough);
            let outs = execute_node(&node.op, algo, &ins)
                .map_err(|e| anyhow::anyhow!("node {} ({}): {e}", id.0, node.name))?;
            for (port, t) in outs.into_iter().enumerate() {
                values.insert((id.0, port), t);
            }
            // Reclaim tensors whose consumers have all run.
            for p in &node.inputs {
                let r = &mut remaining[p.node.0];
                *r = r.saturating_sub(1);
                if *r == 0 {
                    let ports = g.node(p.node).op.num_outputs();
                    for port in 0..ports {
                        values.remove(&(p.node.0, port));
                    }
                }
            }
        }
        let outputs = g
            .outputs
            .iter()
            .map(|p| {
                values
                    .get(&(p.node.0, p.port))
                    .or_else(|| plan.constants.get(&(p.node.0, p.port)))
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("output {:?} not computed", p))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(RunOutput { outputs, wall_s: t0.elapsed().as_secs_f64() })
    }

    /// Plan + run in one call.
    pub fn run(
        &self,
        g: &Graph,
        a: &Assignment,
        inputs: &[Tensor],
    ) -> anyhow::Result<RunOutput> {
        let plan = self.plan(g, a)?;
        self.run_plan(g, a, &plan, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::AlgorithmRegistry;
    use crate::graph::op::eps_bits;
    use crate::graph::{Activation, PortRef};
    use crate::subst::RuleSet;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    fn conv(act: Activation, bias: bool) -> OpKind {
        OpKind::Conv2d { stride: (1, 1), pad: (1, 1), act, has_bias: bias, has_residual: false }
    }

    fn build_small_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 3, 8, 8] }, &[], "x");
        let w1 = g.add1(OpKind::weight(vec![4, 3, 3, 3], 1), &[], "w1");
        let c1 = g.add1(conv(Activation::None, false), &[x, w1], "c1");
        let r1 = g.add1(OpKind::Relu, &[c1], "r1");
        let gamma = g.add1(OpKind::weight_kind(vec![4], 2, crate::graph::op::WeightKind::Gamma), &[], "gamma");
        let beta = g.add1(OpKind::weight_kind(vec![4], 3, crate::graph::op::WeightKind::Beta), &[], "beta");
        let mean = g.add1(OpKind::weight_kind(vec![4], 4, crate::graph::op::WeightKind::Mean), &[], "mean");
        let var = g.add1(OpKind::weight_kind(vec![4], 5, crate::graph::op::WeightKind::Var), &[], "var");
        let bn = g.add1(OpKind::BatchNorm { eps: eps_bits(1e-5) }, &[r1, gamma, beta, mean, var], "bn");
        let p = g.add1(OpKind::MaxPool { k: (2, 2), stride: (2, 2), pad: (0, 0) }, &[bn], "pool");
        g.outputs = vec![PortRef::of(p)];
        g.validate().unwrap();
        g
    }

    #[test]
    fn runs_and_produces_shapes() {
        let g = build_small_graph();
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let eng = ReferenceEngine::new();
        let mut rng = Rng::seed_from(1);
        let x = Tensor::rand(&[1, 3, 8, 8], &mut rng, -1.0, 1.0);
        let out = eng.run(&g, &a, &[x]).unwrap();
        assert_eq!(out.outputs.len(), 1);
        assert_eq!(out.outputs[0].shape(), &[1, 4, 4, 4]);
        assert!(out.outputs[0].all_finite());
    }

    #[test]
    fn deterministic_across_runs() {
        let g = build_small_graph();
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let eng = ReferenceEngine::new();
        let mut rng = Rng::seed_from(2);
        let x = Tensor::rand(&[1, 3, 8, 8], &mut rng, -1.0, 1.0);
        let o1 = eng.run(&g, &a, &[x.clone()]).unwrap();
        let o2 = eng.run(&g, &a, &[x]).unwrap();
        assert_eq!(o1.outputs[0], o2.outputs[0]);
    }

    #[test]
    fn algorithm_choice_does_not_change_semantics() {
        let g = build_small_graph();
        let reg = AlgorithmRegistry::new();
        let a0 = Assignment::default_for(&g, &reg);
        let mut a1 = a0.clone();
        // switch the conv to every applicable algorithm and compare
        let conv_id = g
            .nodes()
            .find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. }))
            .unwrap()
            .0;
        let eng = ReferenceEngine::new();
        let mut rng = Rng::seed_from(3);
        let x = Tensor::rand(&[1, 3, 8, 8], &mut rng, -1.0, 1.0);
        let base = eng.run(&g, &a0, &[x.clone()]).unwrap();
        for algo in [Algorithm::ConvDirect, Algorithm::ConvWinograd] {
            a1.set(conv_id, algo);
            let out = eng.run(&g, &a1, &[x.clone()]).unwrap();
            assert_close(base.outputs[0].data(), out.outputs[0].data(), 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn substituted_graphs_equivalent_end_to_end() {
        // Full-loop check: every neighbor produced by the rule set computes
        // the same function as the original graph.
        let g = build_small_graph();
        let reg = AlgorithmRegistry::new();
        let eng = ReferenceEngine::new();
        let mut rng = Rng::seed_from(4);
        let x = Tensor::rand(&[1, 3, 8, 8], &mut rng, -1.0, 1.0);
        let base = eng.run(&g, &Assignment::default_for(&g, &reg), &[x.clone()]).unwrap();
        let rs = RuleSet::standard();
        let neighbors = rs.neighbors(&g).unwrap();
        assert!(!neighbors.is_empty(), "expected at least one substitution");
        for (ng, rule) in neighbors {
            let a = Assignment::default_for(&ng, &reg);
            let out = eng.run(&ng, &a, &[x.clone()]).unwrap();
            assert_close(base.outputs[0].data(), out.outputs[0].data(), 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("rule {rule} broke equivalence: {e}"));
        }
    }

    #[test]
    fn wrong_input_shape_rejected() {
        let g = build_small_graph();
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let eng = ReferenceEngine::new();
        let bad = Tensor::zeros(&[1, 3, 4, 4]);
        assert!(eng.run(&g, &a, &[bad]).is_err());
    }

    #[test]
    fn wrong_input_count_rejected() {
        let g = build_small_graph();
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let eng = ReferenceEngine::new();
        assert!(eng.run(&g, &a, &[]).is_err());
    }
}
