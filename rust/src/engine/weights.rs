//! Deterministic weight realization.
//!
//! Every `Weight { shape, seed, kind }` node materializes to the same tensor
//! in every process and backend: tensor data is drawn from an Rng seeded by
//! `seed`, with a distribution chosen by `kind` (a BN variance must be
//! positive, a gamma near one, a filter He-scaled). The JAX side
//! (`python/compile/model.py`) reproduces the same scheme so PJRT artifacts
//! and the reference engine agree bit-for-bit on inputs.

use crate::graph::op::WeightKind;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Materialize a weight tensor.
pub fn realize(shape: &[usize], seed: u64, kind: WeightKind) -> Tensor {
    let mut rng = Rng::seed_from(0xEAD6_0000_0000_0000 ^ seed);
    match kind {
        WeightKind::Filter => {
            // He-uniform: limit = sqrt(6 / fan_in).
            let fan_in: usize = match shape.len() {
                4 => shape[1] * shape[2] * shape[3],
                2 => shape[0],
                _ => shape.iter().product::<usize>().max(1),
            };
            let limit = (6.0 / fan_in.max(1) as f32).sqrt();
            Tensor::rand(shape, &mut rng, -limit, limit)
        }
        WeightKind::Bias | WeightKind::Beta | WeightKind::Mean => {
            Tensor::rand(shape, &mut rng, -0.1, 0.1)
        }
        WeightKind::Gamma => Tensor::rand(shape, &mut rng, 0.8, 1.2),
        WeightKind::Var => Tensor::rand(shape, &mut rng, 0.5, 1.5),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_calls() {
        let a = realize(&[4, 3, 3, 3], 7, WeightKind::Filter);
        let b = realize(&[4, 3, 3, 3], 7, WeightKind::Filter);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = realize(&[8], 1, WeightKind::Bias);
        let b = realize(&[8], 2, WeightKind::Bias);
        assert_ne!(a, b);
    }

    #[test]
    fn var_strictly_positive() {
        let v = realize(&[64], 99, WeightKind::Var);
        assert!(v.data().iter().all(|&x| x >= 0.5 && x <= 1.5));
    }

    #[test]
    fn gamma_near_one() {
        let g = realize(&[64], 5, WeightKind::Gamma);
        assert!(g.data().iter().all(|&x| (0.8..=1.2).contains(&x)));
    }

    #[test]
    fn filter_he_scaled() {
        let f = realize(&[16, 64, 3, 3], 3, WeightKind::Filter);
        let limit = (6.0f32 / (64.0 * 9.0)).sqrt();
        assert!(f.data().iter().all(|&x| x.abs() <= limit));
        // and not degenerate
        assert!(f.data().iter().any(|&x| x.abs() > limit * 0.5));
    }
}
