//! `eadgo` — the energy-aware DNN graph optimizer CLI (leader entrypoint).
//!
//! Subcommands:
//!   optimize   Optimize a zoo model for an objective; print the result.
//!   reproduce  Regenerate a paper table (--table 1..5, or `all`).
//!   profile    Populate the profile database for a model.
//!   constrain  Min-energy search under a time budget (binary search on w).
//!   run        Execute a model through the engine (reference or PJRT).
//!   show       Dump a model's computation graph.
//!   zoo        List available models.

use eadgo::algo::Assignment;
use eadgo::config::RunConfig;
use eadgo::cost::CostDb;
use eadgo::models;
use eadgo::profiler::{CpuProvider, SimHeteroProvider, SimV100Provider};
use eadgo::report::tables::{self, ExperimentConfig};
use eadgo::report::f3;
use eadgo::runtime::Runtime;
use eadgo::search::{
    optimize, optimize_with_time_budget, OptimizerContext, PlanFrontier, PlanPoint,
};
use eadgo::tensor::Tensor;
use eadgo::util::cli::Args;
use eadgo::util::rng::Rng;

fn main() {
    let args = Args::from_env(true);
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> anyhow::Result<()> {
    validate_args(args)?;
    match args.subcommand.as_deref() {
        Some("optimize") => cmd_optimize(args),
        Some("reproduce") => cmd_reproduce(args),
        Some("profile") => cmd_profile(args),
        Some("constrain") => cmd_constrain(args),
        Some("run") => cmd_run(args),
        Some("serve") => cmd_serve(args),
        Some("show") => cmd_show(args),
        Some("zoo") => {
            println!("available models: {}", models::zoo_names().join(", "));
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand `{other}`\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// Options shared by every model-driven subcommand (RunConfig overrides).
const COMMON_OPTS: &[&str] = &[
    "model",
    "objective",
    "alpha",
    "inner-distance",
    "max-dequeues",
    "threads",
    "dvfs",
    "incremental-inner",
    "seed",
    "db",
    "artifacts",
    "provider",
    "devices",
    "layouts",
    "resolution",
    "width-div",
    "batch",
    "config",
];

/// Reject mistyped flags up front so the user gets the usage text back
/// instead of a silently-ignored option (or a panic downstream).
fn validate_args(args: &Args) -> anyhow::Result<()> {
    let extra: &[&str] = match args.subcommand.as_deref() {
        Some("optimize") => &["save-plan", "frontier", "save-frontier", "batches"],
        Some("reproduce") => {
            return args
                .require_known(&["table", "quick", "seed"])
                .map_err(|e| anyhow::anyhow!("{e}\n\n{USAGE}"));
        }
        Some("profile") | Some("show") => &[],
        Some("constrain") => &["time-budget", "probes"],
        Some("run") => &["iters", "plan"],
        Some("serve") => &[
            "plan",
            "optimize",
            "requests",
            "batch-max",
            "rate",
            "max-wait-ms",
            "burst",
            "frontier",
            "adaptive",
            "feedback",
            "drift-threshold",
            "research-interval",
            "truth-db",
            "save-research",
            "fault-plan",
        ],
        Some("zoo") => {
            return args.require_known(&[]).map_err(|e| anyhow::anyhow!("{e}\n\n{USAGE}"));
        }
        _ => return Ok(()), // unknown subcommand / bare call handled in run()
    };
    let mut allowed: Vec<&str> = COMMON_OPTS.to_vec();
    allowed.extend_from_slice(extra);
    args.require_known(&allowed).map_err(|e| anyhow::anyhow!("{e}\n\n{USAGE}"))
}

const USAGE: &str = "\
eadgo — energy-aware DNN graph optimization (Wang, Ge, Qiu; ReCoML@MLSys'20 reproduction)

USAGE: eadgo <subcommand> [--options]

  optimize  --model M --objective (time|energy|power|linear:W|power_energy:W)
            [--alpha 1.05] [--inner-distance D] [--max-dequeues N]
            [--threads T] [--dvfs off|per-graph|per-node]
            [--incremental-inner on|off] [--frontier N]
            [--batches 1,2,4,8] [--save-frontier plans.json]
            [--db profiles.json] [--provider sim|cpu] [--devices gpu,dla]
            [--layouts nchw,nhwc] [--config run.json]
  reproduce --table (1|2|3|4|5|all) [--quick] [--seed S]
  profile   --model M [--provider sim|cpu] [--db profiles.json]
  constrain --model M --time-budget MS [--probes 8] [--threads T]
            [--dvfs off|per-graph|per-node] [--devices gpu,dla]
            [--layouts nchw,nhwc]
  run       --model M [--artifacts DIR] [--iters N]
  serve     --model M [--plan plan.json] [--frontier plans.json]
            [--adaptive] [--optimize [OBJ]] [--requests N]
            [--batch-max B] [--rate HZ] [--max-wait-ms MS]
            [--burst R1:N1,R2:N2,...] [--feedback on|off]
            [--drift-threshold X] [--research-interval S]
            [--truth-db costs.json] [--save-research plans.json]
            [--fault-plan faults.json]
            [--artifacts DIR] [--threads T]
  show      --model M
  zoo

  --threads T parallelizes candidate evaluation in the outer search
  (T=0 means one worker per core); with the deterministic sim provider
  the optimized plan is bit-identical for every T (cpu measurements are
  noisy by nature). --dvfs adds the GPU core clock to the search space:
  per-graph locks one frequency state for the whole plan, per-node lets
  every node pick its own state jointly with its algorithm (memory-bound
  nodes down-clock for free). constrain uses frequency as the cheapest
  lever when the time budget binds. optimize accepts --save-plan
  out.json to persist the optimized (graph, assignment, frequencies);
  run/serve accept --plan to load it back. serve --optimize runs the
  optimizer first and serves the result, sharing one warm cost oracle
  across optimize and serve.

  --incremental-inner off disables the warm-start/memoized inner-search
  engine and re-derives every node's (algorithm, frequency) choice cold
  — the A/B reference; plans are bit-identical either way for additive
  objectives. optimize prints the inner-search economy (warm vs cold
  starts, dirty vs total nodes swept, argmin cache hit rate).

  optimize --frontier N enumerates an N-point pareto frontier over
  (latency, energy) instead of a single plan — sweep the energy weight,
  prune dominated candidates — and --save-frontier persists it
  (versioned JSON; a --save-plan file loads as a 1-point frontier).
  serve --frontier plans.json serves its energy-optimal plan; add
  --adaptive to let a controller watch the live request rate and queue
  depth and switch the active plan across the frontier (energy-optimal
  under light load, latency-optimal under pressure, with hysteresis).
  serve --optimize --adaptive builds a 4-point frontier inline.

  optimize --frontier N --batches 1,4,8 sweeps batch size jointly with
  the plan and frequency: every plan is priced at every batch (weights
  amortize, activations scale) and the frontier becomes a surface of
  (plan, freq, batch) operating points over (batch latency,
  energy/request), saved as a v3 manifest with per-plan batch. Serving
  such a frontier with --adaptive turns on deadline-aware batching:
  the controller picks an operating point from live queue depth and
  arrival rate, the dispatcher targets its batch size but never holds
  the oldest request past --max-wait-ms (admission control), and each
  formed batch is charged the oracle's price at its actual size.
  --burst RATE:COUNT,... replays a piecewise-rate Poisson trace (e.g.
  calm:burst:calm) instead of the single --rate process; phases define
  the request count, so --requests/--rate are rejected alongside it.
  serve defaults honor config keys serve_batch_max / serve_max_wait_ms.

  --devices gpu,dla (sim provider only) adds a DLA-class accelerator as
  a per-node placement axis: the search places every node on a device
  jointly with its algorithm and frequency, charging a transfer cost
  (shared-DRAM link) wherever adjacent nodes land on different devices.
  The list must start with gpu; `--devices gpu` is the default and is
  bit-identical to omitting the flag. With --dvfs off the placement
  search runs at each device's nominal clock; with --dvfs per-node the
  device's own clock table joins the space. constrain with --devices
  uses migration (e.g. pull a node back to the GPU when the budget
  binds, or push it to the DLA when energy is the objective) as a
  feasibility lever alongside frequency. Plans that place nodes off-GPU
  save as v4 manifests with a per-node device array; serving one
  requires the same --devices list, and all-GPU plans stay byte-stable.

  --layouts nchw,nhwc (sim providers only) adds the tensor memory layout
  as a per-node cost axis: every node may run NCHW or NHWC, the sim
  reprices its memory path per layout (NHWC favors tensor-core-friendly
  conv and matmul shapes, NCHW favors depthwise), and every edge whose
  endpoints disagree is charged an implicit transpose. The search picks
  (algorithm, frequency, device, layout) jointly. The list must start
  with nchw; `--layouts nchw` is the default and is bit-identical to
  omitting the flag. Plans that assign NHWC anywhere save as v5
  manifests with a per-node layout array; single-layout plans stay
  byte-stable.

  serve --feedback on closes the optimize->serve loop into a
  self-tuning server: every executed batch feeds its measured service
  time into a drift detector against the oracle's predicted cost;
  sustained drift writes measured rows back into the cost database
  (provenance-tagged), re-prices the served surface against the
  corrected costs, and hot-swaps the controller's frontier between
  batches without dropping a request. With --optimize the re-search
  runs the full two-level search (warm-started from the active plan)
  instead of re-pricing, and --save-research persists the re-searched
  surface as a noted frontier manifest. --drift-threshold X sets the
  relative-error trip point; --research-interval S throttles
  re-searches (virtual seconds). --truth-db costs.json serves under a
  deterministic virtual service model priced from a separate ground
  truth cost database — the drift-injection harness: serve plans whose
  --db mispredicts the truth and watch the loop correct it. Config
  keys serve_feedback / serve_drift_threshold provide the defaults.

  serve --fault-plan faults.json replays a deterministic, seeded fault
  script against the session (the fault-injection harness, mirroring
  --truth-db): timestamped device_lost / thermal_cap / power_cap /
  transient_error events. Device loss masks every state on the lost
  device and hot-swaps to surviving plans — or to the manifest's
  contingency plans (synthesized by optimize --frontier --devices at
  --save-frontier time, persisted as a v6 manifest) — without dropping
  an admitted request. Thermal and power caps clamp the device clock
  and re-price the surface against the capped cost table. Transient
  errors retry with deterministic exponential backoff and shed
  deadline-blown requests; every fault, degradation, and shed lands as
  a typed event in the report. Fault serving prices the surface like
  --feedback on does (it needs the oracle and the plan graphs), and a
  run without --fault-plan is byte-identical to not having the
  harness at all.
";

fn load_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn build_context(cfg: &RunConfig) -> anyhow::Result<OptimizerContext> {
    let db = CostDb::load_or_default(&cfg.db_path);
    let multi_device = cfg.devices.len() > 1;
    let provider: Box<dyn eadgo::profiler::CostProvider> = match cfg.provider.as_str() {
        "sim" if multi_device => Box::new(SimHeteroProvider::new(cfg.seed)),
        "sim" => Box::new(SimV100Provider::new(cfg.seed)),
        "cpu" if multi_device => anyhow::bail!(
            "--devices {} needs the sim provider; the cpu provider measures one real device",
            cfg.devices.join(",")
        ),
        "cpu" if cfg.layouts.len() > 1 => anyhow::bail!(
            "--layouts {} needs the sim provider; the cpu provider measures one real layout",
            cfg.layouts.join(",")
        ),
        "cpu" => Box::new(CpuProvider::new(None)),
        other => anyhow::bail!("unknown provider `{other}` (sim|cpu)"),
    };
    Ok(OptimizerContext::new(eadgo::subst::RuleSet::standard(), db, provider))
}

fn get_model(cfg: &RunConfig) -> anyhow::Result<eadgo::graph::Graph> {
    models::by_name(&cfg.model, cfg.model_cfg)
        .ok_or_else(|| anyhow::anyhow!("unknown model `{}` — try `eadgo zoo`", cfg.model))
}

fn cmd_optimize(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let g0 = get_model(&cfg)?;
    let objective = cfg.cost_function()?;
    let ctx = build_context(&cfg)?;
    let scfg = cfg.search_config();
    anyhow::ensure!(
        !args.flag("frontier"),
        "--frontier expects a point count, e.g. `--frontier 5`"
    );
    anyhow::ensure!(
        !args.flag("save-frontier"),
        "--save-frontier expects a path, e.g. `--save-frontier plans.json`"
    );
    anyhow::ensure!(
        !args.flag("batches"),
        "--batches expects a batch-size list, e.g. `--batches 1,2,4,8`"
    );
    if let Some(nspec) = args.get("frontier") {
        // Refuse combinations we would otherwise silently ignore (the
        // strict-flag policy: no option is accepted and then dropped).
        anyhow::ensure!(
            args.get("save-plan").is_none(),
            "--frontier produces a plan set; use --save-frontier, not --save-plan"
        );
        anyhow::ensure!(
            args.get("objective").is_none(),
            "--frontier sweeps the whole energy/time weight range; drop --objective"
        );
        let n: usize = nspec
            .parse()
            .map_err(|_| anyhow::anyhow!("--frontier expects a point count, got `{nspec}`"))?;
        return cmd_optimize_frontier(args, &cfg, &g0, &ctx, &scfg, n);
    }
    anyhow::ensure!(
        args.get("save-frontier").is_none(),
        "--save-frontier requires --frontier N"
    );
    anyhow::ensure!(args.get("batches").is_none(), "--batches requires --frontier N");
    // Single-device runs keep the historical header byte-for-byte; the
    // devices note only appears when placement is actually in play.
    let dev_note = if cfg.devices.len() > 1 {
        format!(", devices={}", cfg.devices.join("+"))
    } else {
        String::new()
    };
    // Same policy for layouts: the note appears only when the axis is on.
    let lay_note = if cfg.layouts.len() > 1 {
        format!(", layouts={}", cfg.layouts.join("+"))
    } else {
        String::new()
    };
    println!(
        "optimizing {} ({} nodes) for {} (alpha={}, provider={}{dev_note}{lay_note}, threads={}, dvfs={})",
        cfg.model,
        g0.runtime_node_count(),
        objective.describe(),
        cfg.alpha,
        cfg.provider,
        scfg.effective_threads(),
        scfg.dvfs.describe()
    );
    let res = optimize(&g0, &ctx, &objective, &scfg)?;
    println!(
        "origin:    time {} ms  power {} W  energy {} J/1k",
        f3(res.original.time_ms),
        f3(res.original.power_w()),
        f3(res.original.energy_j)
    );
    println!(
        "optimized: time {} ms  power {} W  energy {} J/1k",
        f3(res.cost.time_ms),
        f3(res.cost.power_w()),
        f3(res.cost.energy_j)
    );
    println!(
        "objective improved {:.1}%  (energy {:+.1}%, time {:+.1}%)",
        100.0 * res.objective_savings(),
        -100.0 * res.energy_savings(),
        -100.0 * res.time_savings(),
    );
    if !matches!(scfg.dvfs, eadgo::search::DvfsMode::Off)
        || res.assignment.uses_non_gpu_device()
        || res.assignment.uses_non_default_layout()
    {
        println!("plan frequency: {}", eadgo::report::describe_freqs(&res.assignment));
    }
    println!(
        "search: {} graphs expanded in {} waves, {} generated, {} deduped, {} profiles measured, {} threads, {:.2}s ({:.0} candidates/sec)",
        res.stats.expanded,
        res.stats.waves,
        res.stats.generated,
        res.stats.deduped,
        res.stats.profiled,
        res.stats.threads,
        res.stats.wall_s,
        res.stats.candidates_per_sec()
    );
    if !res.stats.rule_stats.is_empty() {
        print!("{}", tables::rule_stats_table(&res.stats).render());
    }
    print!("{}", tables::inner_stats_table(&res.stats).render());
    if let Some(path) = args.get("save-plan") {
        eadgo::graph::serde::save_plan(std::path::Path::new(path), &res.graph, &res.assignment)?;
        println!("optimized plan saved to {path}");
    }
    ctx.oracle.save_db(&cfg.db_path)?;
    println!(
        "profile db saved to {} ({} entries)",
        cfg.db_path.display(),
        ctx.oracle.db_entries()
    );
    Ok(())
}

/// Parse `--batches 1,2,4,8` (strict-flag policy: every element must be
/// an integer; range/ordering rules are enforced by the search layer).
fn parse_batches(spec: &str) -> anyhow::Result<Vec<usize>> {
    spec.split(',')
        .map(|part| {
            part.trim().parse::<usize>().map_err(|_| {
                anyhow::anyhow!(
                    "--batches expects a comma-separated batch-size list, e.g. `--batches 1,2,4,8`, got `{part}`"
                )
            })
        })
        .collect()
}

/// Parse `--burst RATE:COUNT,RATE:COUNT,...` into arrival phases.
fn parse_burst(spec: &str) -> anyhow::Result<Vec<eadgo::serve::RatePhase>> {
    spec.split(',')
        .map(|part| {
            let (rate, count) = part.split_once(':').ok_or_else(|| {
                anyhow::anyhow!(
                    "--burst expects RATE:COUNT phases, e.g. `--burst 100:32,2000:192,100:32`, got `{part}`"
                )
            })?;
            let rate_hz: f64 = rate
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--burst phase rate `{rate}` is not a number"))?;
            let requests: usize = count
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--burst phase count `{count}` is not an integer"))?;
            anyhow::ensure!(
                rate_hz.is_finite() && rate_hz > 0.0,
                "--burst phase rate must be a positive finite req/s, got `{rate}`"
            );
            anyhow::ensure!(requests >= 1, "--burst phase count must be >= 1");
            Ok(eadgo::serve::RatePhase::new(rate_hz, requests))
        })
        .collect()
}

/// `optimize --frontier N`: enumerate a pareto frontier instead of a
/// single plan (the --objective flag is ignored — the sweep covers the
/// whole energy/time weight range).
fn cmd_optimize_frontier(
    args: &Args,
    cfg: &RunConfig,
    g0: &eadgo::graph::Graph,
    ctx: &OptimizerContext,
    scfg: &eadgo::search::SearchConfig,
    n: usize,
) -> anyhow::Result<()> {
    let batches = match args.get("batches") {
        Some(spec) => parse_batches(spec)?,
        None => vec![1],
    };
    if batches == [1] {
        println!(
            "enumerating a {n}-point pareto frontier for {} ({} nodes; alpha={}, provider={}, threads={}, dvfs={})",
            cfg.model,
            g0.runtime_node_count(),
            cfg.alpha,
            cfg.provider,
            scfg.effective_threads(),
            scfg.dvfs.describe()
        );
    } else {
        println!(
            "enumerating a {n}-point-per-batch operating surface for {} over batches {:?} ({} nodes; alpha={}, provider={}, threads={}, dvfs={})",
            cfg.model,
            batches,
            g0.runtime_node_count(),
            cfg.alpha,
            cfg.provider,
            scfg.effective_threads(),
            scfg.dvfs.describe()
        );
    }
    let res = eadgo::search::optimize_frontier_batched(g0, ctx, scfg, n, &batches)?;
    print!("{}", tables::frontier_table(&res.frontier, Some(&res.original)).render());
    println!("probes:");
    for p in &res.probes {
        println!(
            "  w_energy={:.2}  batch={}  time {} ms  energy {} J/1k  search {:.2}s",
            p.weight,
            p.batch,
            f3(p.cost.time_ms),
            f3(p.cost.energy_j),
            p.wall_s
        );
    }
    if let Some(path) = args.get("save-frontier") {
        // Plans that place nodes on an accelerator get a device-loss
        // contingency synthesized alongside them: an all-GPU fallback the
        // serve loop can hot-swap to if the accelerator drops off. All-GPU
        // frontiers synthesize nothing and the manifest bytes are
        // unchanged (v2–v5 as before; any contingency upgrades to v6).
        let conts = res
            .frontier
            .points()
            .iter()
            .map(|p| {
                Ok(eadgo::search::synthesize_contingency(
                    &ctx.oracle,
                    &p.graph,
                    &p.assignment,
                    scfg.dvfs,
                )?
                .map(|(assignment, cost)| eadgo::runtime::manifest::ContingencyPlan {
                    graph: p.graph.clone(),
                    assignment,
                    cost,
                }))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let n_conts = conts.iter().filter(|c| c.is_some()).count();
        eadgo::runtime::manifest::save_frontier_with_contingencies(
            std::path::Path::new(path),
            &res.frontier,
            &conts,
        )?;
        if n_conts > 0 {
            println!(
                "frontier ({} plans, {n_conts} device-loss contingency plan(s)) saved to {path}",
                res.frontier.len()
            );
        } else {
            println!("frontier ({} plans) saved to {path}", res.frontier.len());
        }
    }
    ctx.oracle.save_db(&cfg.db_path)?;
    println!(
        "profile db saved to {} ({} entries)",
        cfg.db_path.display(),
        ctx.oracle.db_entries()
    );
    Ok(())
}

fn cmd_reproduce(args: &Args) -> anyhow::Result<()> {
    let which = args.get_or("table", "all");
    let mut ecfg = if args.flag("quick") {
        ExperimentConfig::quick()
    } else {
        ExperimentConfig::default()
    };
    ecfg.seed = args.get_f64("seed", ecfg.seed as f64)? as u64;
    let run_one = |n: u32| -> anyhow::Result<String> {
        Ok(match n {
            1 => tables::table1(&ecfg).0.render(),
            2 => tables::table2(&ecfg).0.render(),
            3 => tables::table3(&ecfg).0.render(),
            4 => tables::table4(&ecfg).0.render(),
            5 => tables::table5(&ecfg).0.render(),
            _ => anyhow::bail!("no table {n} in the paper (1-5)"),
        })
    };
    if which == "all" {
        for n in 1..=5 {
            println!("{}", run_one(n)?);
        }
    } else {
        let n: u32 = which.parse().map_err(|_| anyhow::anyhow!("--table expects 1..5 or all"))?;
        println!("{}", run_one(n)?);
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let g = get_model(&cfg)?;
    let ctx = build_context(&cfg)?;
    let rep = ctx.oracle.profile_graph(&g)?;
    println!(
        "profiled {}: {} new measurements, {} cached, db now {} entries / {} signatures",
        cfg.model,
        rep.measured,
        rep.cached,
        ctx.oracle.db_entries(),
        ctx.oracle.db_signatures()
    );
    ctx.oracle.save_db(&cfg.db_path)?;
    println!("saved {}", cfg.db_path.display());
    Ok(())
}

fn cmd_constrain(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let budget = args.get_f64("time-budget", f64::NAN)?;
    anyhow::ensure!(budget.is_finite(), "--time-budget MS is required");
    let probes = args.get_usize("probes", 8)?;
    let g0 = get_model(&cfg)?;
    let ctx = build_context(&cfg)?;
    let r = optimize_with_time_budget(&g0, &ctx, budget, &cfg.search_config(), probes)?;
    if !r.feasible {
        println!(
            "infeasible: best achievable time {} ms > budget {} ms (returning best-time solution)",
            f3(r.result.cost.time_ms),
            f3(budget)
        );
    } else {
        println!(
            "feasible at w={:.4}: time {} ms (budget {}), energy {} J/1k",
            r.weight,
            f3(r.result.cost.time_ms),
            f3(budget),
            f3(r.result.cost.energy_j)
        );
        if !matches!(cfg.dvfs, eadgo::search::DvfsMode::Off)
            || r.result.assignment.uses_non_gpu_device()
            || r.result.assignment.uses_non_default_layout()
        {
            println!("plan frequency: {}", eadgo::report::describe_freqs(&r.result.assignment));
        }
    }
    println!("probe trace (w, time_ms, energy):");
    for (w, t, e) in &r.trace {
        println!("  w={w:.4}  t={}  e={}", f3(*t), f3(*e));
    }
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let iters = args.get_usize("iters", 10)?;
    let reg = eadgo::algo::AlgorithmRegistry::new();
    // Either a persisted optimized plan or a zoo model with defaults.
    let (g, a) = match args.get("plan") {
        Some(path) => eadgo::graph::serde::load_plan(std::path::Path::new(path), &reg)?,
        None => {
            let g = get_model(&cfg)?;
            let a = Assignment::default_for(&g, &reg);
            (g, a)
        }
    };
    let mut rng = Rng::seed_from(cfg.seed);
    let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!(e))?;
    let shape = g
        .nodes()
        .find_map(|(id, n)| {
            matches!(n.op, eadgo::graph::OpKind::Input { .. }).then(|| shapes[id.0][0].clone())
        })
        .ok_or_else(|| anyhow::anyhow!("graph has no input"))?;
    let x = Tensor::rand(&shape, &mut rng, -1.0, 1.0);

    let manifest_path = cfg.artifacts_dir.join("manifest.json");
    if manifest_path.exists() {
        let mut rt = Runtime::cpu()?;
        let n = rt.load_dir(&cfg.artifacts_dir)?;
        println!("loaded {n} artifacts on {}", rt.platform());
        let engine = eadgo::engine::pjrt::PjrtEngine::new(&rt);
        let mut total = 0.0;
        let mut stats = Default::default();
        for _ in 0..iters {
            let (out, s) = engine.run(&g, &a, std::slice::from_ref(&x))?;
            total += out.wall_s;
            stats = s;
        }
        println!(
            "pjrt-hybrid: {} ms/inference over {iters} iters ({} pjrt nodes, {} reference nodes)",
            f3(total / iters as f64 * 1e3),
            stats.pjrt_nodes,
            stats.reference_nodes
        );
    } else {
        println!("no artifacts at {} — reference engine only", manifest_path.display());
        let engine = eadgo::engine::ReferenceEngine::new();
        let plan = engine.plan(&g, &a)?;
        let mut total = 0.0;
        for _ in 0..iters {
            let out = engine.run_plan(&g, &a, &plan, std::slice::from_ref(&x))?;
            total += out.wall_s;
        }
        println!("reference: {} ms/inference over {iters} iters", f3(total / iters as f64 * 1e3));
    }
    Ok(())
}

fn cmd_show(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let g = get_model(&cfg)?;
    print!("{}", g.dump());
    println!(
        "{} nodes ({} runtime), {} outputs",
        g.len(),
        g.runtime_node_count(),
        g.outputs.len()
    );
    Ok(())
}

/// Resolve what `serve` should put behind the request loop: a frontier of
/// one or more plans (single-plan sources load as a one-point frontier),
/// plus each plan's device-loss contingency when the source carries them
/// (v6 frontier manifests; all-`None` otherwise), index-aligned with the
/// frontier's points.
fn serve_frontier_source(
    args: &Args,
    cfg: &RunConfig,
    ctx: &OptimizerContext,
    reg: &eadgo::algo::AlgorithmRegistry,
) -> anyhow::Result<(PlanFrontier, Vec<Option<eadgo::runtime::manifest::ContingencyPlan>>)> {
    // The strict-flag policy again: a mis-shaped flag must error, not be
    // silently reinterpreted.
    anyhow::ensure!(
        args.get("adaptive").is_none(),
        "--adaptive is a bare flag and takes no value"
    );
    anyhow::ensure!(
        !args.flag("frontier"),
        "--frontier expects a path, e.g. `--frontier plans.json`"
    );
    let adaptive = args.flag("adaptive");
    let want_optimize = args.flag("optimize") || args.get("optimize").is_some();
    let single = |g: eadgo::graph::Graph,
                  a: Assignment|
     -> anyhow::Result<(PlanFrontier, Vec<Option<eadgo::runtime::manifest::ContingencyPlan>>)> {
        let cost = ctx.oracle.cached_cost(&g, &a)?.unwrap_or_default();
        let f = PlanFrontier::from_points(vec![PlanPoint {
            graph: g,
            assignment: a,
            cost,
            weight: 1.0,
            batch: 1,
        }]);
        Ok((f, Vec::new()))
    };
    if let Some(path) = args.get("frontier") {
        // Refuse plan sources we would otherwise silently ignore.
        anyhow::ensure!(
            args.get("plan").is_none(),
            "--frontier and --plan are mutually exclusive plan sources"
        );
        anyhow::ensure!(!want_optimize, "--frontier serves saved plans; drop --optimize");
        let (f, conts) =
            eadgo::runtime::manifest::load_frontier_full(std::path::Path::new(path), reg)?;
        let n_conts = conts.iter().filter(|c| c.is_some()).count();
        if n_conts > 0 {
            println!(
                "loaded {}-point frontier from {path} ({n_conts} device-loss contingency plan(s))",
                f.len()
            );
        } else {
            println!("loaded {}-point frontier from {path}", f.len());
        }
        return Ok((f, conts));
    }
    if let Some(path) = args.get("plan") {
        anyhow::ensure!(
            !adaptive,
            "serve --adaptive needs a frontier: use --frontier plans.json or --optimize"
        );
        anyhow::ensure!(!want_optimize, "--plan and --optimize are mutually exclusive");
        let (g, a) = eadgo::graph::serde::load_plan(std::path::Path::new(path), reg)?;
        return single(g, a);
    }
    if want_optimize {
        let g0 = get_model(cfg)?;
        if adaptive {
            anyhow::ensure!(
                args.get("objective").is_none(),
                "--optimize --adaptive sweeps the whole energy/time weight range; drop --objective"
            );
            println!(
                "optimizing a 4-point pareto frontier of {} before serving (threads={})",
                cfg.model,
                cfg.search_config().effective_threads()
            );
            let res = eadgo::search::optimize_frontier(&g0, ctx, &cfg.search_config(), 4)?;
            print!("{}", tables::frontier_table(&res.frontier, Some(&res.original)).render());
            return Ok((res.frontier, Vec::new()));
        }
        // `--optimize` uses the configured --objective; `--optimize OBJ`
        // names the objective inline.
        let objective = match args.get("optimize") {
            Some(spec) => eadgo::config::parse_objective(spec)?,
            None => cfg.cost_function()?,
        };
        println!(
            "optimizing {} for {} before serving (threads={})",
            cfg.model,
            objective.describe(),
            cfg.search_config().effective_threads()
        );
        let res = optimize(&g0, ctx, &objective, &cfg.search_config())?;
        println!(
            "optimized: energy {:+.1}%, time {:+.1}% vs origin",
            -100.0 * res.energy_savings(),
            -100.0 * res.time_savings()
        );
        return single(res.graph, res.assignment);
    }
    anyhow::ensure!(
        !adaptive,
        "serve --adaptive needs a frontier: use --frontier plans.json or --optimize"
    );
    let g = get_model(cfg)?;
    let a = Assignment::default_for(&g, reg);
    single(g, a)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let reg = eadgo::algo::AlgorithmRegistry::new();
    // One context for the whole subcommand: when `--optimize` is set, the
    // optimizer warms the oracle and the serving path reuses it — no
    // re-profiling between optimize and serve.
    let ctx = build_context(&cfg)?;
    let adaptive = args.flag("adaptive");
    let (frontier, frontier_conts) = serve_frontier_source(args, &cfg, &ctx, &reg)?;
    anyhow::ensure!(!frontier.is_empty(), "no plan to serve");
    // --fault-plan: deterministic seeded fault injection (the robustness
    // mirror of --truth-db). Strict-flag policy as everywhere else.
    anyhow::ensure!(
        !args.flag("fault-plan"),
        "--fault-plan expects a path, e.g. `--fault-plan faults.json`"
    );
    let fault_plan = match args.get("fault-plan") {
        Some(path) => Some(eadgo::serve::FaultPlan::load(std::path::Path::new(path))?),
        None => None,
    };
    // Placement guard: a mixed-device plan priced on a single-device cost
    // grid would silently drop its transfer and DLA terms — reject it and
    // tell the user which --devices list reproduces the plan's grid.
    let missing = eadgo::runtime::manifest::unsupported_devices(&frontier, &cfg.devices);
    if !missing.is_empty() {
        let mut want = cfg.devices.clone();
        want.extend(missing.iter().cloned());
        anyhow::bail!(
            "plan places nodes on device(s) [{}] the serving context does not provide — \
             re-run with --devices {}",
            missing.join(", "),
            want.join(",")
        );
    }
    if adaptive && frontier.len() == 1 {
        println!("note: single-plan frontier — adaptive serving degenerates to fixed-plan");
    }
    // Adaptive mode serves the whole frontier; fixed mode serves its
    // energy-optimal plan (for single-plan sources that IS the plan).
    let points: Vec<&PlanPoint> = if adaptive {
        frontier.points().iter().collect()
    } else {
        vec![frontier.energy_optimal()]
    };
    let costs: Vec<eadgo::cost::GraphCost> = points.iter().map(|p| p.cost).collect();
    // Contingencies ride along only under a fault plan, re-aligned with
    // whichever points are actually served (all of them when adaptive,
    // just the energy-optimal plan otherwise — the frontier's last point).
    let cont_points: Option<Vec<Option<PlanPoint>>> = fault_plan.as_ref().map(|_| {
        let to_point = |c: &eadgo::runtime::manifest::ContingencyPlan| PlanPoint {
            graph: c.graph.clone(),
            assignment: c.assignment.clone(),
            cost: c.cost,
            weight: 1.0,
            batch: 1,
        };
        if adaptive {
            (0..frontier.len())
                .map(|i| frontier_conts.get(i).and_then(Option::as_ref).map(to_point))
                .collect()
        } else {
            let last = frontier.len() - 1;
            vec![frontier_conts.get(last).and_then(Option::as_ref).map(to_point)]
        }
    });
    if let Some(fp) = &fault_plan {
        println!(
            "fault plan: {} event(s), max {} retries, backoff {} ms ({} contingency plan(s) armed)",
            fp.events.len(),
            fp.max_retries,
            fp.backoff_ms,
            cont_points.iter().flatten().flatten().count()
        );
    }

    let g0 = &points[0].graph;
    let shapes = g0.infer_shapes().map_err(|e| anyhow::anyhow!(e))?;
    let input_shape = g0
        .nodes()
        .find_map(|(id, n)| {
            matches!(n.op, eadgo::graph::OpKind::Input { .. }).then(|| shapes[id.0][0].clone())
        })
        .ok_or_else(|| anyhow::anyhow!("graph has no input"))?;

    // Strict serve-knob validation: out-of-range values are CLI errors,
    // never silent clamps. Config keys serve_batch_max / serve_max_wait_ms
    // provide the defaults; flags override.
    let requests = args.get_usize("requests", 64)?;
    anyhow::ensure!(requests >= 1, "--requests must be >= 1");
    let batch_max = args.get_usize("batch-max", cfg.serve_batch_max)?;
    anyhow::ensure!(
        (1..=4096).contains(&batch_max),
        "--batch-max must be in 1..=4096, got {batch_max}"
    );
    let rate = args.get_f64("rate", 500.0)?;
    anyhow::ensure!(
        rate.is_finite() && rate > 0.0,
        "--rate must be a positive finite req/s, got {rate}"
    );
    let max_wait_ms = args.get_f64("max-wait-ms", cfg.serve_max_wait_ms)?;
    anyhow::ensure!(
        max_wait_ms.is_finite() && max_wait_ms >= 0.0,
        "--max-wait-ms must be finite and >= 0, got {max_wait_ms}"
    );
    let phases = match args.get("burst") {
        Some(spec) => {
            anyhow::ensure!(
                args.get("requests").is_none(),
                "--burst phases define the request count; drop --requests"
            );
            anyhow::ensure!(
                args.get("rate").is_none(),
                "--burst phases define the arrival rate; drop --rate"
            );
            parse_burst(spec)?
        }
        None => Vec::new(),
    };
    // Feedback-loop knobs, same strict policy. `--feedback on` turns the
    // session into a self-tuning server; the feedback-only options are
    // rejected (not silently ignored) without it.
    anyhow::ensure!(!args.flag("feedback"), "--feedback expects on|off, e.g. `--feedback on`");
    let feedback_on = match args.get("feedback") {
        Some("on") | Some("true") | Some("1") => true,
        Some("off") | Some("false") | Some("0") => false,
        Some(other) => anyhow::bail!("--feedback expects on|off, got `{other}`"),
        None => cfg.serve_feedback,
    };
    anyhow::ensure!(!args.flag("drift-threshold"), "--drift-threshold expects a number");
    anyhow::ensure!(!args.flag("research-interval"), "--research-interval expects seconds");
    anyhow::ensure!(!args.flag("truth-db"), "--truth-db expects a path");
    anyhow::ensure!(!args.flag("save-research"), "--save-research expects a path");
    if !feedback_on {
        for opt in ["drift-threshold", "research-interval", "truth-db", "save-research"] {
            anyhow::ensure!(args.get(opt).is_none(), "--{opt} requires --feedback on");
        }
    }
    let drift_threshold = args.get_f64("drift-threshold", cfg.serve_drift_threshold)?;
    anyhow::ensure!(
        drift_threshold.is_finite() && drift_threshold > 0.0,
        "--drift-threshold must be finite and > 0, got {drift_threshold}"
    );
    let research_interval = args.get_f64("research-interval", 0.5)?;
    anyhow::ensure!(
        research_interval.is_finite() && research_interval >= 0.0,
        "--research-interval must be finite and >= 0 (virtual seconds), got {research_interval}"
    );
    let want_optimize = args.flag("optimize") || args.get("optimize").is_some();
    anyhow::ensure!(
        args.get("save-research").is_none() || want_optimize,
        "--save-research saves a re-searched surface; it requires --optimize (full re-search)"
    );
    let fbcfg = feedback_on.then(|| eadgo::serve::FeedbackConfig {
        drift_threshold,
        drift_clear: drift_threshold * 0.4,
        research_interval_s: research_interval,
        background: false,
        ..Default::default()
    });
    // --truth-db: deterministic virtual service model priced from a
    // separate ground-truth cost database (the drift-injection harness).
    let truth_service = match args.get("truth-db") {
        Some(path) => {
            let path = std::path::Path::new(path);
            anyhow::ensure!(path.exists(), "--truth-db {}: file not found", path.display());
            // The truth oracle must span the same device grid as the
            // serving context, or mixed-device plans would be priced
            // without their DLA and transfer terms.
            let truth_provider: Box<dyn eadgo::profiler::CostProvider> =
                if cfg.devices.len() > 1 {
                    Box::new(SimHeteroProvider::new(cfg.seed))
                } else {
                    Box::new(SimV100Provider::new(cfg.seed))
                };
            let truth = eadgo::cost::CostOracle::new(
                eadgo::algo::AlgorithmRegistry::new(),
                CostDb::load_or_default(path),
                truth_provider,
            );
            let per_batch_ms = points
                .iter()
                .map(|p| {
                    (1..=batch_max)
                        .map(|m| {
                            eadgo::search::price_plan_at_batch(&truth, &p.graph, &p.assignment, m)
                                .map(|c| c.time_ms)
                        })
                        .collect::<anyhow::Result<Vec<_>>>()
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            println!(
                "virtual service model from truth db {} ({} plan(s) x batches 1..={batch_max})",
                path.display(),
                per_batch_ms.len()
            );
            Some(eadgo::serve::ServiceModel::Virtual { per_batch_ms, scale_s_per_ms: 1e-3 })
        }
        None => None,
    };
    let scfg = eadgo::serve::ServeConfig {
        requests,
        batch_max,
        arrival_rate_hz: rate,
        max_wait_s: max_wait_ms * 1e-3,
        seed: cfg.seed,
        input_shape,
        phases,
        service: truth_service.unwrap_or(eadgo::serve::ServiceModel::Wallclock),
    };
    let policy = eadgo::serve::AdaptiveConfig::default();
    let use_controller = adaptive && points.len() > 1;
    // A batched frontier behind --adaptive serves (plan, batch) operating
    // points with deadline-aware batch formation instead of the plain
    // plan-switching loop.
    let use_ops = adaptive && points.iter().any(|p| p.batch > 1);
    let ops: Vec<eadgo::serve::OperatingPoint> = points
        .iter()
        .enumerate()
        .map(|(i, p)| eadgo::serve::OperatingPoint { plan: i, batch: p.batch })
        .collect();
    let grid: Vec<Vec<eadgo::cost::GraphCost>> = if use_ops {
        println!(
            "serving {} operating points (batches {:?}, dispatcher cap {batch_max})",
            ops.len(),
            ops.iter().map(|o| o.batch).collect::<Vec<_>>()
        );
        points
            .iter()
            .map(|p| {
                (1..=p.batch.min(batch_max))
                    .map(|m| {
                        eadgo::search::price_plan_at_batch(
                            &ctx.oracle,
                            &p.graph,
                            &p.assignment,
                            m,
                        )
                    })
                    .collect::<anyhow::Result<Vec<_>>>()
            })
            .collect::<anyhow::Result<Vec<_>>>()?
    } else {
        Vec::new()
    };

    // Owned copies of the served points: the feedback session can hot-swap
    // the surface mid-run, so exec/adopt share a mutable plan store rather
    // than borrowing the loaded frontier directly.
    let owned: Vec<PlanPoint> = points.iter().map(|&p| p.clone()).collect();
    // --optimize upgrades drift-triggered re-search from re-pricing to the
    // full two-level search, warm-started from the active plan.
    let research = if feedback_on && want_optimize {
        let mut rbatches: Vec<usize> = owned.iter().map(|p| p.batch).collect();
        rbatches.sort_unstable();
        rbatches.dedup();
        Some(eadgo::serve::ResearchConfig {
            ctx: &ctx,
            origin: get_model(&cfg)?,
            search: cfg.search_config(),
            points: owned.len().max(2),
            batches: rbatches,
        })
    } else {
        None
    };
    // Stash of the last adopted (fully re-searched) surface, for
    // --save-research and the post-run summary.
    let researched: std::cell::RefCell<Option<Vec<PlanPoint>>> = std::cell::RefCell::new(None);

    let manifest_path = cfg.artifacts_dir.join("manifest.json");
    let report = if manifest_path.exists() {
        let mut rt = Runtime::cpu()?;
        let n = rt.load_dir(&cfg.artifacts_dir)?;
        println!("serving via PJRT-hybrid engine ({n} artifacts)");
        let engine = eadgo::engine::pjrt::PjrtEngine::new(&rt);
        let prepared = owned
            .iter()
            .map(|p| engine.prepare(&p.graph, &p.assignment))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let state = std::cell::RefCell::new((owned.clone(), prepared));
        let exec = |idx: usize, batch: &[Tensor]| -> anyhow::Result<Vec<Tensor>> {
            let st = state.borrow();
            let (pts, plans) = &*st;
            let p = &pts[idx];
            let mut outs = Vec::with_capacity(batch.len());
            for x in batch {
                let xs = std::slice::from_ref(x);
                let (o, _) = engine.run_prepared(&p.graph, &p.assignment, &plans[idx], xs)?;
                let y = o
                    .outputs
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("engine returned no outputs"))?;
                outs.push(y);
            }
            Ok(outs)
        };
        let adopt = |pts: &[PlanPoint]| -> anyhow::Result<()> {
            let plans = pts
                .iter()
                .map(|p| engine.prepare(&p.graph, &p.assignment))
                .collect::<anyhow::Result<Vec<_>>>()?;
            *state.borrow_mut() = (pts.to_vec(), plans);
            *researched.borrow_mut() = Some(pts.to_vec());
            Ok(())
        };
        run_serve_session(
            &scfg, &ctx.oracle, &owned, fbcfg, research, use_ops, use_controller, &costs, &grid,
            &ops, &policy, adaptive, fault_plan.clone(), cont_points.clone(), exec, adopt,
        )?
    } else {
        println!("serving via reference engine (no artifacts at {})", manifest_path.display());
        let engine = eadgo::engine::ReferenceEngine::new();
        let plans = owned
            .iter()
            .map(|p| engine.plan(&p.graph, &p.assignment))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let state = std::cell::RefCell::new((owned.clone(), plans));
        let exec = |idx: usize, batch: &[Tensor]| -> anyhow::Result<Vec<Tensor>> {
            let st = state.borrow();
            let (pts, plans) = &*st;
            let p = &pts[idx];
            let mut outs = Vec::with_capacity(batch.len());
            for x in batch {
                let xs = std::slice::from_ref(x);
                let o = engine.run_plan(&p.graph, &p.assignment, &plans[idx], xs)?;
                let y = o
                    .outputs
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("engine returned no outputs"))?;
                outs.push(y);
            }
            Ok(outs)
        };
        let adopt = |pts: &[PlanPoint]| -> anyhow::Result<()> {
            let plans = pts
                .iter()
                .map(|p| engine.plan(&p.graph, &p.assignment))
                .collect::<anyhow::Result<Vec<_>>>()?;
            *state.borrow_mut() = (pts.to_vec(), plans);
            *researched.borrow_mut() = Some(pts.to_vec());
            Ok(())
        };
        run_serve_session(
            &scfg, &ctx.oracle, &owned, fbcfg, research, use_ops, use_controller, &costs, &grid,
            &ops, &policy, adaptive, fault_plan, cont_points, exec, adopt,
        )?
    };

    let lat = report.latency_summary();
    println!(
        "served {} requests in {} batches (mean batch {:.2})",
        report.records.len(),
        report.batches,
        report.mean_batch_size()
    );
    println!(
        "latency p50 {} ms  p95 {} ms  p99 {} ms  mean {} ms   throughput {:.1} req/s   engine busy {:.2}s",
        f3(lat.p50 * 1e3),
        f3(lat.p95 * 1e3),
        f3(lat.p99 * 1e3),
        f3(lat.mean * 1e3),
        report.throughput_rps(),
        report.busy_s
    );
    if let Some(est) = report.plan_cost {
        // est.energy_j is J per 1000 inferences — numerically mJ/request.
        println!(
            "oracle estimate for served plan: time {} ms  power {} W  energy/request {} mJ at {}",
            f3(est.time_ms),
            f3(est.power_w()),
            f3(est.energy_j),
            eadgo::report::describe_freqs(&points[0].assignment)
        );
    }
    if use_controller || use_ops || (feedback_on && adaptive) {
        println!(
            "adaptive controller: {} {} switch(es), request distribution {}",
            report.switches.len(),
            if use_ops || feedback_on { "operating-point" } else { "plan" },
            report.plan_distribution()
        );
        for s in &report.switches {
            println!(
                "  t={:.4}s  p{} -> p{}  (queue {}, rate {:.0} req/s)",
                s.at_s, s.from, s.to, s.queue_depth, s.rate_hz
            );
        }
        if let Some(e) = report.energy_mj_per_request {
            println!("oracle-estimated energy/request served: {} mJ", f3(e));
        }
        if let Some(rpj) = report.requests_per_joule() {
            println!("oracle-estimated requests/joule: {}", f3(rpj));
        }
    }
    if feedback_on {
        println!(
            "feedback: {} drift event(s), {} hot-swap(s), {} measured rows",
            report.drift_events.len(),
            report.swaps.len(),
            report.feedback_rows
        );
        for d in &report.drift_events {
            println!(
                "  t={:.4}s  plan {}  drift {}  (rel_err {:.3}, observed/predicted {:.3})",
                d.at_s,
                d.plan,
                match d.kind {
                    eadgo::serve::DriftKind::Detected => "detected",
                    eadgo::serve::DriftKind::Cleared => "cleared",
                },
                d.rel_err,
                d.ratio
            );
        }
        for s in &report.swaps {
            println!(
                "  t={:.4}s  hot-swap to epoch {} ({})  energy/request {} -> {} mJ",
                s.at_s,
                s.epoch,
                if s.researched { "re-searched" } else { "re-priced" },
                f3(s.energy_mj_before),
                f3(s.energy_mj_after)
            );
        }
        match (researched.borrow().as_ref(), args.get("save-research")) {
            (Some(pts), Some(path)) => {
                let f = PlanFrontier::from_points(pts.clone());
                eadgo::runtime::manifest::save_frontier_noted(
                    std::path::Path::new(path),
                    &f,
                    "feedback-research",
                )?;
                println!("re-searched frontier ({} plans) saved to {path}", f.len());
            }
            (None, Some(_)) => {
                println!("no re-searched surface to save (drift never triggered a full re-search)");
            }
            _ => {}
        }
    }
    if args.get("fault-plan").is_some() {
        println!(
            "faults: {} injected, {} degradation(s), {} request(s) shed, availability {:.4}",
            report.faults.len(),
            report.degrades.len(),
            report.sheds.len(),
            report.availability()
        );
        for f in &report.faults {
            println!("  t={:.4}s  fault {}", f.at_s, f.to_json().to_string_compact());
        }
        for d in &report.degrades {
            println!(
                "  t={:.4}s  degrade {} (epoch {}, plans {} -> {}, {} contingency hot-swap(s)){}",
                d.at_s,
                d.cause.describe(),
                d.epoch,
                d.points_before,
                d.points_after,
                d.contingencies_used,
                if d.detail.is_empty() { String::new() } else { format!(": {}", d.detail) }
            );
        }
        for s in &report.sheds {
            println!(
                "  t={:.4}s  shed request {} after {} retries (waited {} ms)",
                s.at_s,
                s.id,
                s.retries,
                f3(s.waited_s * 1e3)
            );
        }
    }
    Ok(())
}

/// Compose and run the [`ServeSession`](eadgo::serve::ServeSession) for
/// `cmd_serve`: one call site for both engines. With feedback on, the
/// session serves the full plan points (graphs included) so the loop can
/// write measured costs back and hot-swap the surface; a fault plan
/// forces the same composition (the fault path needs the oracle and
/// graphs to mask and re-price the surface, and `run_with_adopt` so a
/// device-loss contingency can be handed to the executor); otherwise the
/// legacy-equivalent fixed/frontier/operating-point composition applies.
#[allow(clippy::too_many_arguments)]
fn run_serve_session<F, G>(
    scfg: &eadgo::serve::ServeConfig,
    oracle: &eadgo::cost::CostOracle,
    owned: &[PlanPoint],
    feedback: Option<eadgo::serve::FeedbackConfig>,
    research: Option<eadgo::serve::ResearchConfig<'_>>,
    use_ops: bool,
    use_controller: bool,
    costs: &[eadgo::cost::GraphCost],
    grid: &[Vec<eadgo::cost::GraphCost>],
    ops: &[eadgo::serve::OperatingPoint],
    policy: &eadgo::serve::AdaptiveConfig,
    adaptive: bool,
    faults: Option<eadgo::serve::FaultPlan>,
    contingencies: Option<Vec<Option<PlanPoint>>>,
    exec: F,
    adopt: G,
) -> anyhow::Result<eadgo::serve::ServeReport>
where
    F: FnMut(usize, &[Tensor]) -> anyhow::Result<Vec<Tensor>>,
    G: FnMut(&[PlanPoint]) -> anyhow::Result<()>,
{
    let session = eadgo::serve::ServeSession::new(scfg);
    match (feedback, faults) {
        (Some(fb), faults) => {
            let mut s = session.oracle(oracle).plan_points(owned).feedback(fb);
            if adaptive {
                s = s.adaptive(policy.clone());
            }
            if let Some(fp) = faults {
                s = s.faults(fp);
            }
            if let Some(conts) = contingencies {
                s = s.contingencies(conts);
            }
            match research {
                Some(rc) => s.research(rc).run_with_adopt(exec, adopt),
                None => s.run_with_adopt(exec, adopt),
            }
        }
        (None, Some(fp)) => {
            // Every serve mode routes through the fault-tolerant plan-point
            // composition under a fault plan: priced like feedback's
            // ops-ified surface, hot-swappable through `adopt`.
            let mut s = session.oracle(oracle).plan_points(owned).adaptive(policy.clone()).faults(fp);
            if let Some(conts) = contingencies {
                s = s.contingencies(conts);
            }
            s.run_with_adopt(exec, adopt)
        }
        (None, None) => {
            if use_ops {
                session.operating_points(grid, ops).adaptive(policy.clone()).run(exec)
            } else if use_controller {
                session.frontier_costs(costs).adaptive(policy.clone()).run(exec)
            } else {
                session.oracle(oracle).plan(&owned[0].graph, &owned[0].assignment).run(exec)
            }
        }
    }
}
