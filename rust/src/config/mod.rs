//! Run configuration: a JSON config file merged with CLI overrides — the
//! "real config system" for the launcher (`eadgo` CLI).

use crate::cost::CostFunction;
use crate::models::ModelConfig;
use crate::search::{DvfsMode, SearchConfig};
use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

/// Everything an optimizer invocation needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Zoo model name (see `eadgo zoo`).
    pub model: String,
    /// Objective spec (`energy`, `linear:0.5`, ...; see [`parse_objective`]).
    pub objective: String,
    /// Outer-search relaxation factor.
    pub alpha: f64,
    /// Inner-search distance override (`None` = paper recommendation).
    pub inner_distance: Option<usize>,
    /// Hard cap on dequeued outer-search states.
    pub max_dequeues: usize,
    /// Search worker threads (1 = sequential, 0 = one per core). With a
    /// deterministic provider (sim) the optimized plan is identical for
    /// every value; only wall-clock moves.
    pub threads: usize,
    /// DVFS frequency search: off, per-graph, or per-node.
    pub dvfs: DvfsMode,
    /// Incremental inner search (warm starts + argmin memo); `false`
    /// forces the cold full re-derivation reference. Plans are
    /// bit-identical either way for additive objectives.
    pub incremental_inner: bool,
    /// Seed for providers and synthetic inputs.
    pub seed: u64,
    /// Model scale configuration.
    pub model_cfg: ModelConfig,
    /// Profile database path (loaded if present, saved after runs).
    pub db_path: PathBuf,
    /// AOT artifacts directory.
    pub artifacts_dir: PathBuf,
    /// Cost provider: "sim" (V100 model) or "cpu" (real measurement).
    pub provider: String,
    /// Device classes the search may place nodes on, in device-index
    /// order (`["gpu"]` = classic single-device search; `["gpu", "dla"]`
    /// adds per-node placement with transfer-aware boundaries). Parsed /
    /// validated by [`parse_devices`]; only meaningful with the sim
    /// provider.
    pub devices: Vec<String>,
    /// Tensor layouts the search may assign per node, in layout-index
    /// order (`["nchw"]` = classic single-layout search; `["nchw",
    /// "nhwc"]` adds per-node layout with transpose-aware boundaries).
    /// Parsed / validated by [`parse_layouts`]; only meaningful with the
    /// sim providers.
    pub layouts: Vec<String>,
    /// Default dispatcher batch cap for `eadgo serve` (CLI `--batch-max`
    /// overrides).
    pub serve_batch_max: usize,
    /// Default batch-fill window for `eadgo serve`, milliseconds (CLI
    /// `--max-wait-ms` overrides).
    pub serve_max_wait_ms: f64,
    /// Default for the `eadgo serve` feedback loop (CLI `--feedback`
    /// overrides): telemetry writeback, drift detection, re-search.
    pub serve_feedback: bool,
    /// Default drift-detection threshold (relative error) for the serve
    /// feedback loop (CLI `--drift-threshold` overrides).
    pub serve_drift_threshold: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "squeezenet".into(),
            objective: "energy".into(),
            alpha: 1.05,
            inner_distance: None,
            max_dequeues: 400,
            threads: 1,
            dvfs: DvfsMode::Off,
            incremental_inner: true,
            seed: 7,
            model_cfg: ModelConfig::default(),
            db_path: PathBuf::from("profiles.json"),
            artifacts_dir: PathBuf::from("artifacts"),
            provider: "sim".into(),
            devices: vec!["gpu".into()],
            layouts: vec!["nchw".into()],
            serve_batch_max: 4,
            serve_max_wait_ms: 2.0,
            serve_feedback: false,
            serve_drift_threshold: 0.25,
        }
    }
}

impl RunConfig {
    /// Parse the objective string: `time`, `energy`, `power`,
    /// `linear:<w-on-energy>`, `product:<w>`, `power_energy:<w>`.
    pub fn cost_function(&self) -> anyhow::Result<CostFunction> {
        parse_objective(&self.objective)
    }

    /// Expand into a full [`SearchConfig`].
    pub fn search_config(&self) -> SearchConfig {
        // `["nchw"]` is the classic single-layout search: leave the axis
        // off (empty vec) so every search surface stays byte-identical to
        // the pre-layout builds. Non-default layouts switch it on.
        let layouts: Vec<crate::energysim::Layout> = if self.layouts.len() > 1 {
            self.layouts
                .iter()
                .filter_map(|s| crate::energysim::Layout::parse(s))
                .collect()
        } else {
            Vec::new()
        };
        SearchConfig {
            alpha: self.alpha,
            inner_distance: self.inner_distance,
            max_dequeues: self.max_dequeues,
            threads: self.threads,
            dvfs: self.dvfs,
            incremental_inner: self.incremental_inner,
            layouts,
            ..Default::default()
        }
    }

    /// Load from a JSON file; missing fields keep defaults.
    pub fn load(path: &Path) -> anyhow::Result<RunConfig> {
        let v = json::read_file(path)?;
        let mut cfg = RunConfig::default();
        if let Some(s) = v.get("model").and_then(Json::as_str) {
            cfg.model = s.to_string();
        }
        if let Some(s) = v.get("objective").and_then(Json::as_str) {
            cfg.objective = s.to_string();
        }
        if let Some(x) = v.get("alpha").and_then(Json::as_f64) {
            cfg.alpha = x;
        }
        if let Some(x) = v.get("inner_distance").and_then(Json::as_usize) {
            cfg.inner_distance = Some(x);
        }
        if let Some(x) = v.get("max_dequeues").and_then(Json::as_usize) {
            cfg.max_dequeues = x;
        }
        if let Some(x) = v.get("threads").and_then(Json::as_usize) {
            cfg.threads = x;
        }
        if let Some(s) = v.get("dvfs").and_then(Json::as_str) {
            cfg.dvfs = DvfsMode::parse(s)?;
        }
        if let Some(b) = v.get("incremental_inner").and_then(Json::as_bool) {
            cfg.incremental_inner = b;
        }
        if let Some(x) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = x as u64;
        }
        if let Some(s) = v.get("db_path").and_then(Json::as_str) {
            cfg.db_path = PathBuf::from(s);
        }
        if let Some(s) = v.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = v.get("provider").and_then(Json::as_str) {
            cfg.provider = s.to_string();
        }
        if let Some(d) = v.get("devices") {
            let spec = match d {
                Json::Str(s) => s.clone(),
                Json::Arr(items) => items
                    .iter()
                    .map(|i| {
                        i.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow::anyhow!("devices: entries must be strings"))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?
                    .join(","),
                _ => anyhow::bail!("devices: expected a string or an array of strings"),
            };
            cfg.devices = parse_devices(&spec)?;
        }
        if let Some(d) = v.get("layouts") {
            let spec = match d {
                Json::Str(s) => s.clone(),
                Json::Arr(items) => items
                    .iter()
                    .map(|i| {
                        i.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow::anyhow!("layouts: entries must be strings"))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?
                    .join(","),
                _ => anyhow::bail!("layouts: expected a string or an array of strings"),
            };
            cfg.layouts = parse_layouts(&spec)?;
        }
        if let Some(x) = v.get("serve_batch_max").and_then(Json::as_usize) {
            anyhow::ensure!(x >= 1, "serve_batch_max must be >= 1");
            cfg.serve_batch_max = x;
        }
        if let Some(x) = v.get("serve_max_wait_ms").and_then(Json::as_f64) {
            anyhow::ensure!(
                x.is_finite() && x >= 0.0,
                "serve_max_wait_ms must be finite and >= 0"
            );
            cfg.serve_max_wait_ms = x;
        }
        if let Some(b) = v.get("serve_feedback").and_then(Json::as_bool) {
            cfg.serve_feedback = b;
        }
        if let Some(x) = v.get("serve_drift_threshold").and_then(Json::as_f64) {
            anyhow::ensure!(
                x.is_finite() && x > 0.0,
                "serve_drift_threshold must be finite and > 0"
            );
            cfg.serve_drift_threshold = x;
        }
        if let Some(m) = v.get("model_config") {
            if let Some(x) = m.get("batch").and_then(Json::as_usize) {
                cfg.model_cfg.batch = x;
            }
            if let Some(x) = m.get("resolution").and_then(Json::as_usize) {
                cfg.model_cfg.resolution = x;
            }
            if let Some(x) = m.get("width_div").and_then(Json::as_usize) {
                cfg.model_cfg.width_div = x;
            }
            if let Some(x) = m.get("classes").and_then(Json::as_usize) {
                cfg.model_cfg.classes = x;
            }
        }
        Ok(cfg)
    }

    /// Apply CLI overrides on top.
    pub fn apply_args(&mut self, args: &crate::util::cli::Args) -> anyhow::Result<()> {
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(o) = args.get("objective") {
            self.objective = o.to_string();
        }
        self.alpha = args.get_f64("alpha", self.alpha)?;
        self.max_dequeues = args.get_usize("max-dequeues", self.max_dequeues)?;
        self.threads = args.get_usize("threads", self.threads)?;
        if let Some(s) = args.get("dvfs") {
            self.dvfs = DvfsMode::parse(s)?;
        }
        if let Some(s) = args.get("incremental-inner") {
            self.incremental_inner = match s {
                "on" | "true" | "1" => true,
                "off" | "false" | "0" => false,
                other => anyhow::bail!("--incremental-inner expects on|off, got `{other}`"),
            };
        }
        self.seed = args.get_f64("seed", self.seed as f64)? as u64;
        if let Some(d) = args.get("inner-distance") {
            self.inner_distance = Some(
                d.parse()
                    .map_err(|_| anyhow::anyhow!("--inner-distance expects an integer"))?,
            );
        }
        if let Some(p) = args.get("db") {
            self.db_path = PathBuf::from(p);
        }
        if let Some(p) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(p);
        }
        if let Some(p) = args.get("provider") {
            self.provider = p.to_string();
        }
        if let Some(d) = args.get("devices") {
            self.devices = parse_devices(d)?;
        }
        if let Some(l) = args.get("layouts") {
            self.layouts = parse_layouts(l)?;
        }
        self.model_cfg.resolution = args.get_usize("resolution", self.model_cfg.resolution)?;
        self.model_cfg.width_div = args.get_usize("width-div", self.model_cfg.width_div)?;
        self.model_cfg.batch = args.get_usize("batch", self.model_cfg.batch)?;
        Ok(())
    }
}

/// Parse a `--devices` spec: comma-separated device-class names (`gpu`,
/// or `gpu,dla`). The GPU must come first — it is device index 0, which
/// anchors the packed nominal states — and names must be unique. Unknown
/// names fail with a did-you-mean against the known device classes.
pub fn parse_devices(spec: &str) -> anyhow::Result<Vec<String>> {
    let known = crate::energysim::DEVICE_NAMES;
    let mut out: Vec<String> = Vec::new();
    for raw in spec.split(',') {
        let name = raw.trim().to_ascii_lowercase();
        anyhow::ensure!(!name.is_empty(), "devices: empty device name in `{spec}`");
        if crate::energysim::DeviceId::parse(&name).is_none() {
            let mut best: Option<(&str, usize)> = None;
            for k in known {
                let d = edit_distance(k, &name);
                if best.is_none_or(|(_, bd)| d < bd) {
                    best = Some((k, d));
                }
            }
            let hint = match best {
                Some((k, d)) if d <= 2 => format!(" — did you mean `{k}`?"),
                _ => String::new(),
            };
            anyhow::bail!(
                "devices: unknown device `{name}`{hint} (known: {})",
                known.join(", ")
            );
        }
        anyhow::ensure!(!out.contains(&name), "devices: duplicate device `{name}`");
        out.push(name);
    }
    anyhow::ensure!(
        out.first().map(String::as_str) == Some("gpu"),
        "devices: the list must start with `gpu` (device 0 anchors the nominal states)"
    );
    Ok(out)
}

/// Parse a `--layouts` spec: comma-separated layout names (`nchw`, or
/// `nchw,nhwc`). NCHW must come first — it is layout bit 0, which keeps
/// every packed state byte-compatible with pre-layout plans — and names
/// must be unique. Unknown names fail with a did-you-mean against the
/// known layouts.
pub fn parse_layouts(spec: &str) -> anyhow::Result<Vec<String>> {
    let known = crate::energysim::LAYOUT_NAMES;
    let mut out: Vec<String> = Vec::new();
    for raw in spec.split(',') {
        let name = raw.trim().to_ascii_lowercase();
        anyhow::ensure!(!name.is_empty(), "layouts: empty layout name in `{spec}`");
        if crate::energysim::Layout::parse(&name).is_none() {
            let mut best: Option<(&str, usize)> = None;
            for k in known {
                let d = edit_distance(k, &name);
                if best.is_none_or(|(_, bd)| d < bd) {
                    best = Some((k, d));
                }
            }
            let hint = match best {
                Some((k, d)) if d <= 2 => format!(" — did you mean `{k}`?"),
                _ => String::new(),
            };
            anyhow::bail!(
                "layouts: unknown layout `{name}`{hint} (known: {})",
                known.join(", ")
            );
        }
        anyhow::ensure!(!out.contains(&name), "layouts: duplicate layout `{name}`");
        out.push(name);
    }
    anyhow::ensure!(
        out.first().map(String::as_str) == Some("nchw"),
        "layouts: the list must start with `nchw` (layout 0 anchors the nominal states)"
    );
    Ok(out)
}

/// Levenshtein distance (small inputs only — device-name did-you-mean).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur.push((prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// Parse an objective spec string into a cost function.
pub fn parse_objective(spec: &str) -> anyhow::Result<CostFunction> {
    let (kind, arg) = match spec.split_once(':') {
        Some((k, a)) => (k, Some(a)),
        None => (spec, None),
    };
    let w = || -> anyhow::Result<f64> {
        let a = arg.ok_or_else(|| anyhow::anyhow!("objective `{spec}` needs a weight, e.g. `{kind}:0.5`"))?;
        let w: f64 = a.parse().map_err(|_| anyhow::anyhow!("bad weight `{a}`"))?;
        anyhow::ensure!((0.0..=1.0).contains(&w), "weight must be in [0,1]");
        Ok(w)
    };
    Ok(match kind {
        "time" | "best_time" => CostFunction::Time,
        "energy" | "best_energy" => CostFunction::Energy,
        "power" | "best_power" => CostFunction::Power,
        "linear" => CostFunction::linear(w()?),
        "product" => CostFunction::Product { w: w()? },
        "power_energy" => CostFunction::power_energy(w()?),
        _ => anyhow::bail!(
            "unknown objective `{spec}` (expected time|energy|power|linear:W|product:W|power_energy:W)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_parsing() {
        assert!(matches!(parse_objective("time").unwrap(), CostFunction::Time));
        assert!(matches!(parse_objective("energy").unwrap(), CostFunction::Energy));
        assert!(matches!(parse_objective("power").unwrap(), CostFunction::Power));
        assert!(matches!(
            parse_objective("linear:0.3").unwrap(),
            CostFunction::Linear { .. }
        ));
        assert!(parse_objective("linear").is_err());
        assert!(parse_objective("linear:1.5").is_err());
        assert!(parse_objective("bogus").is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("eadgo_cfg_test");
        let path = dir.join("run.json");
        let mut j = Json::obj();
        j.set("model", "resnet")
            .set("objective", "power_energy:0.5")
            .set("alpha", 1.1)
            .set("max_dequeues", 50usize)
            .set("model_config", {
                let mut m = Json::obj();
                m.set("resolution", 16usize).set("width_div", 8usize);
                m
            });
        json::write_file(&path, &j).unwrap();
        let cfg = RunConfig::load(&path).unwrap();
        assert_eq!(cfg.model, "resnet");
        assert_eq!(cfg.alpha, 1.1);
        assert_eq!(cfg.max_dequeues, 50);
        assert_eq!(cfg.model_cfg.resolution, 16);
        assert!(cfg.cost_function().is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_keys_load_and_validate() {
        let dir = std::env::temp_dir().join("eadgo_cfg_serve_test");
        let path = dir.join("run.json");

        let mut j = Json::obj();
        j.set("serve_batch_max", 16usize)
            .set("serve_max_wait_ms", 0.5)
            .set("serve_feedback", true)
            .set("serve_drift_threshold", 0.4);
        json::write_file(&path, &j).unwrap();
        let cfg = RunConfig::load(&path).unwrap();
        assert_eq!(cfg.serve_batch_max, 16);
        assert_eq!(cfg.serve_max_wait_ms, 0.5);
        assert!(cfg.serve_feedback);
        assert_eq!(cfg.serve_drift_threshold, 0.4);

        // Defaults when absent.
        let d = RunConfig::default();
        assert_eq!(d.serve_batch_max, 4);
        assert_eq!(d.serve_max_wait_ms, 2.0);
        assert!(!d.serve_feedback);
        assert_eq!(d.serve_drift_threshold, 0.25);

        // Out-of-range values are config errors, not silent clamps.
        let mut bad = Json::obj();
        bad.set("serve_batch_max", 0usize);
        json::write_file(&path, &bad).unwrap();
        assert!(RunConfig::load(&path).is_err());
        let mut bad = Json::obj();
        bad.set("serve_max_wait_ms", -1.0);
        json::write_file(&path, &bad).unwrap();
        assert!(RunConfig::load(&path).is_err());
        let mut bad = Json::obj();
        bad.set("serve_drift_threshold", 0.0);
        json::write_file(&path, &bad).unwrap();
        assert!(RunConfig::load(&path).is_err());

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = RunConfig::default();
        let raw = [
            "optimize", "--model", "inception", "--alpha", "1.2", "--objective", "time",
            "--threads", "4", "--dvfs", "per-graph",
        ];
        let args = crate::util::cli::Args::parse(
            &raw.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            true,
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.model, "inception");
        assert_eq!(cfg.alpha, 1.2);
        assert_eq!(cfg.objective, "time");
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.dvfs, DvfsMode::PerGraph);
        assert_eq!(cfg.search_config().dvfs, DvfsMode::PerGraph);
    }

    #[test]
    fn devices_parsing_and_did_you_mean() {
        assert_eq!(parse_devices("gpu").unwrap(), vec!["gpu"]);
        assert_eq!(parse_devices("gpu,dla").unwrap(), vec!["gpu", "dla"]);
        assert_eq!(parse_devices(" GPU , DLA ").unwrap(), vec!["gpu", "dla"]);
        // Unknown names get a did-you-mean against the known classes.
        let err = parse_devices("gpu,dal").unwrap_err().to_string();
        assert!(err.contains("unknown device `dal`"), "{err}");
        assert!(err.contains("did you mean `dla`"), "{err}");
        let err = parse_devices("gpu,tpu").unwrap_err().to_string();
        assert!(err.contains("did you mean `gpu`"), "{err}");
        // Structural constraints: gpu first, no duplicates, no empties.
        assert!(parse_devices("dla").unwrap_err().to_string().contains("start with `gpu`"));
        assert!(parse_devices("dla,gpu").is_err());
        assert!(parse_devices("gpu,gpu").unwrap_err().to_string().contains("duplicate"));
        assert!(parse_devices("gpu,,dla").is_err());
        // Defaults and CLI override.
        assert_eq!(RunConfig::default().devices, vec!["gpu"]);
        let mut cfg = RunConfig::default();
        let args = crate::util::cli::Args::parse(
            &["optimize", "--devices", "gpu,dla"].iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            true,
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.devices, vec!["gpu", "dla"]);
    }

    #[test]
    fn layouts_parsing_and_search_config_wiring() {
        use crate::energysim::Layout;
        assert_eq!(parse_layouts("nchw").unwrap(), vec!["nchw"]);
        assert_eq!(parse_layouts("nchw,nhwc").unwrap(), vec!["nchw", "nhwc"]);
        assert_eq!(parse_layouts(" NCHW , NHWC ").unwrap(), vec!["nchw", "nhwc"]);
        // Unknown names get a did-you-mean against the known layouts.
        let err = parse_layouts("nchw,nhcw").unwrap_err().to_string();
        assert!(err.contains("unknown layout `nhcw`"), "{err}");
        assert!(err.contains("did you mean `nhwc`"), "{err}");
        // Structural constraints: nchw first, no duplicates, no empties.
        assert!(parse_layouts("nhwc").unwrap_err().to_string().contains("start with `nchw`"));
        assert!(parse_layouts("nhwc,nchw").is_err());
        assert!(parse_layouts("nchw,nchw").unwrap_err().to_string().contains("duplicate"));
        assert!(parse_layouts("nchw,,nhwc").is_err());
        // Defaults keep the axis off; the CLI override switches it on.
        let cfg = RunConfig::default();
        assert_eq!(cfg.layouts, vec!["nchw"]);
        assert!(cfg.search_config().layouts.is_empty(), "single-layout must leave the axis off");
        let mut cfg = RunConfig::default();
        let args = crate::util::cli::Args::parse(
            &["optimize", "--layouts", "nchw,nhwc"].iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            true,
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.layouts, vec!["nchw", "nhwc"]);
        assert_eq!(cfg.search_config().layouts, vec![Layout::NCHW, Layout::NHWC]);
        // The JSON config key accepts both spellings, like `devices`.
        let dir = std::env::temp_dir().join("eadgo_cfg_layouts_test");
        let path = dir.join("run.json");
        let mut j = Json::obj();
        j.set("layouts", "nchw,nhwc");
        json::write_file(&path, &j).unwrap();
        assert_eq!(RunConfig::load(&path).unwrap().layouts, vec!["nchw", "nhwc"]);
        let mut j = Json::obj();
        j.set(
            "layouts",
            Json::Arr(vec![Json::Str("nchw".into()), Json::Str("nhwc".into())]),
        );
        json::write_file(&path, &j).unwrap();
        assert_eq!(RunConfig::load(&path).unwrap().layouts, vec!["nchw", "nhwc"]);
        let mut j = Json::obj();
        j.set("layouts", "nchw,chwn");
        json::write_file(&path, &j).unwrap();
        assert!(RunConfig::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn devices_config_key_accepts_string_and_array() {
        let dir = std::env::temp_dir().join("eadgo_cfg_devices_test");
        let path = dir.join("run.json");
        let mut j = Json::obj();
        j.set("devices", "gpu,dla");
        json::write_file(&path, &j).unwrap();
        assert_eq!(RunConfig::load(&path).unwrap().devices, vec!["gpu", "dla"]);
        let mut j = Json::obj();
        j.set(
            "devices",
            Json::Arr(vec![Json::Str("gpu".into()), Json::Str("dla".into())]),
        );
        json::write_file(&path, &j).unwrap();
        assert_eq!(RunConfig::load(&path).unwrap().devices, vec!["gpu", "dla"]);
        // Bad entries are config errors.
        let mut j = Json::obj();
        j.set("devices", "gpu,npu");
        json::write_file(&path, &j).unwrap();
        assert!(RunConfig::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dvfs_parsing() {
        assert_eq!(DvfsMode::parse("off").unwrap(), DvfsMode::Off);
        assert_eq!(DvfsMode::parse("per-graph").unwrap(), DvfsMode::PerGraph);
        assert_eq!(DvfsMode::parse("per_node").unwrap(), DvfsMode::PerNode);
        assert!(DvfsMode::parse("turbo").is_err());
        let mut cfg = RunConfig::default();
        let args = crate::util::cli::Args::parse(
            &["optimize", "--dvfs", "warp9"].iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            true,
        );
        assert!(cfg.apply_args(&args).is_err(), "bad dvfs mode must be a CLI error");
    }
}
