//! PJRT runtime: loads AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py` from JAX/Pallas) and executes them on the PJRT
//! CPU client. Python is never on this path — the artifacts directory is
//! the entire interface.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md).

/// Persisted manifests: AOT artifacts and plan frontiers.
pub mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::path::Path;

/// A compiled artifact ready to execute.
pub struct LoadedArtifact {
    /// The manifest entry this executable was compiled from.
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + a table of compiled executables keyed
/// by artifact key (`<node signature>::<algorithm>` for node kernels,
/// plain names like `model_fwd` for whole-model artifacts).
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: BTreeMap<String, LoadedArtifact>,
}

impl Runtime {
    /// Create a runtime on the PJRT CPU client with no artifacts loaded.
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, artifacts: BTreeMap::new() })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile every artifact listed in `dir/manifest.json`.
    /// Returns the number of artifacts loaded.
    pub fn load_dir(&mut self, dir: &Path) -> anyhow::Result<usize> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let mut n = 0;
        for entry in manifest.entries {
            self.load_entry(dir, entry)?;
            n += 1;
        }
        Ok(n)
    }

    /// Load + compile a single artifact.
    pub fn load_entry(&mut self, dir: &Path, entry: ArtifactEntry) -> anyhow::Result<()> {
        let path = dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", entry.key))?;
        self.artifacts.insert(entry.key.clone(), LoadedArtifact { entry, exe });
        Ok(())
    }

    /// Whether an artifact with this key is loaded.
    pub fn has(&self, key: &str) -> bool {
        self.artifacts.contains_key(key)
    }

    /// All loaded artifact keys, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(String::as_str)
    }

    /// The manifest entry of a loaded artifact.
    pub fn entry(&self, key: &str) -> Option<&ArtifactEntry> {
        self.artifacts.get(key).map(|a| &a.entry)
    }

    /// Number of loaded artifacts.
    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    /// Whether no artifacts are loaded.
    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// Execute an artifact on f32 tensors. Inputs must match the manifest
    /// shapes; outputs are returned in manifest order.
    pub fn execute(&self, key: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let art = self
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("no artifact `{key}` loaded"))?;
        anyhow::ensure!(
            inputs.len() == art.entry.input_shapes.len(),
            "artifact `{key}` expects {} inputs, got {}",
            art.entry.input_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, expect) in inputs.iter().zip(&art.entry.input_shapes) {
            anyhow::ensure!(
                t.shape() == expect.as_slice(),
                "artifact `{key}` input shape {:?} != manifest {:?}",
                t.shape(),
                expect
            );
            literals.push(tensor_to_literal(t)?);
        }
        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing `{key}`: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of `{key}`: {e}"))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of `{key}`: {e}"))?;
        anyhow::ensure!(
            parts.len() == art.entry.output_shapes.len(),
            "artifact `{key}` returned {} outputs, manifest says {}",
            parts.len(),
            art.entry.output_shapes.len()
        );
        parts
            .into_iter()
            .zip(&art.entry.output_shapes)
            .map(|(lit, shape)| literal_to_tensor(&lit, shape))
            .collect()
    }
}

/// Convert a dense f32 tensor to an XLA literal with the same shape.
pub fn tensor_to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("literal reshape {:?}: {e}", t.shape()))
}

/// Convert an XLA literal back to a tensor, checking the element count.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> anyhow::Result<Tensor> {
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?;
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "literal has {} elements, shape {:?} wants {}",
        data.len(),
        shape,
        shape.iter().product::<usize>()
    );
    Ok(Tensor::new(shape.to_vec(), data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit, &[2, 3]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_shape_mismatch_detected() {
        let t = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        let lit = tensor_to_literal(&t).unwrap();
        assert!(literal_to_tensor(&lit, &[5]).is_err());
    }
}
