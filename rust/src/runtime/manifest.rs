//! Persisted runtime contracts: the AOT **artifact manifest** (written by
//! `python/compile/aot.py`, read by the rust runtime, lives at
//! `artifacts/manifest.json`) and the **plan-frontier manifest** (written
//! by `eadgo optimize --frontier N --save-frontier`, read back by
//! `eadgo serve --frontier`).
//!
//! Frontier files are versioned JSON and backward-compatible both ways: a
//! pre-frontier single-plan file (the `--save-plan` format) loads as a
//! one-point frontier, and each entry of a frontier file embeds a complete
//! single-plan document.

use crate::algo::{AlgorithmRegistry, Assignment};
use crate::cost::GraphCost;
use crate::energysim::FreqId;
use crate::graph::serde::{plan_from_json, plan_to_json};
use crate::graph::Graph;
use crate::search::{PlanFrontier, PlanPoint};
use crate::util::json::{self, Json};
use std::path::Path;

/// One AOT artifact: an HLO-text file plus its I/O signature.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Lookup key. Node kernels use `<node signature>::<algorithm>`;
    /// whole-model artifacts use plain names (`model_fwd`).
    pub key: String,
    /// File name relative to the artifacts directory.
    pub file: String,
    /// Expected input tensor shapes, in call order.
    pub input_shapes: Vec<Vec<usize>>,
    /// Produced output tensor shapes.
    pub output_shapes: Vec<Vec<usize>>,
    /// Which kernel implementation the artifact embeds ("pallas_direct",
    /// "pallas_im2col", "pallas_winograd", "jnp", ...). Informational.
    pub kernel: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// All artifacts listed by the manifest, file order.
    pub entries: Vec<ArtifactEntry>,
}

fn shapes_to_json(shapes: &[Vec<usize>]) -> Json {
    Json::Arr(
        shapes
            .iter()
            .map(|s| Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect()))
            .collect(),
    )
}

fn shapes_from_json(v: &Json, what: &str) -> anyhow::Result<Vec<Vec<usize>>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what} not an array"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow::anyhow!("{what} element not an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("{what} dim not a number")))
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Serialize the manifest (versioned object with an `artifacts` array).
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("version", 1i64);
        root.set(
            "artifacts",
            Json::Arr(
                self.entries
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.set("key", e.key.as_str())
                            .set("file", e.file.as_str())
                            .set("inputs", shapes_to_json(&e.input_shapes))
                            .set("outputs", shapes_to_json(&e.output_shapes))
                            .set("kernel", e.kernel.as_str());
                        o
                    })
                    .collect(),
            ),
        );
        root
    }

    /// Parse a manifest document, validating required fields.
    pub fn from_json(v: &Json) -> anyhow::Result<Manifest> {
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing `artifacts`"))?;
        let mut entries = Vec::with_capacity(arts.len());
        for a in arts {
            entries.push(ArtifactEntry {
                key: a.req_str("key")?.to_string(),
                file: a.req_str("file")?.to_string(),
                input_shapes: shapes_from_json(
                    a.get("inputs").unwrap_or(&Json::Null),
                    "inputs",
                )?,
                output_shapes: shapes_from_json(
                    a.get("outputs").unwrap_or(&Json::Null),
                    "outputs",
                )?,
                kernel: a
                    .get("kernel")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
            });
        }
        Ok(Manifest { entries })
    }

    /// Read + parse a manifest file.
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        Manifest::from_json(&json::read_file(path)?)
    }

    /// Serialize + write the manifest to `path`.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        json::write_file(path, &self.to_json())
    }
}

// ---------------------------------------------------------------------------
// Plan-frontier persistence
// ---------------------------------------------------------------------------

/// Frontier-manifest format version for pure batch-1 frontiers. Kept at 2
/// so a frontier with no batch axis serializes byte-identically to the
/// pre-batch-axis writer.
const FRONTIER_VERSION: i64 = 2;

/// Frontier-manifest version once any plan carries a batch size > 1: v3
/// annotates every plan entry with its `batch` operating point. Loaders
/// default a missing `batch` to 1, so v2 files remain readable forever.
const FRONTIER_VERSION_BATCHED: i64 = 3;

/// Frontier-manifest version once any plan places a node off the GPU: v4
/// plan entries embed the per-node `device` array (written/parsed by
/// [`crate::graph::serde::plan_to_json`] / `plan_from_json`, which rejects
/// unknown device names). Loaders treat a missing `device` as all-GPU, so
/// v2/v3 files remain readable forever; all-single-device frontiers keep
/// emitting v2/v3 byte-identically.
const FRONTIER_VERSION_PLACED: i64 = 4;

/// Frontier-manifest version once any plan computes a node in a
/// non-default layout: v5 plan entries embed the per-node `layout` array
/// (written/parsed by the same plan serde, which rejects unknown layout
/// names). Loaders treat a missing `layout` as all-NCHW, so v2/v3/v4 files
/// remain readable forever; all-NCHW frontiers keep emitting their
/// historical version byte-identically.
const FRONTIER_VERSION_LAYOUT: i64 = 5;

/// Frontier-manifest version once any plan carries a device-loss
/// contingency: v6 plan entries may embed a `contingency` object — a
/// complete single-plan document (graph + assignment + cost) the serve
/// loop hot-swaps to when a device the primary plan depends on is lost.
/// Loaders treat a missing `contingency` as "no fallback", so v2–v5 files
/// remain readable forever; contingency-free frontiers keep emitting
/// their historical version byte-identically.
const FRONTIER_VERSION_CONTINGENCY: i64 = 6;

/// Each frontier version's new plan-entry key, for version-gated parsing:
/// a key appearing in a manifest whose declared version predates it is a
/// corrupt or hand-doctored file, rejected rather than silently honored.
const VERSIONED_PLAN_KEYS: [(&str, i64); 4] = [
    ("batch", FRONTIER_VERSION_BATCHED),
    ("device", FRONTIER_VERSION_PLACED),
    ("layout", FRONTIER_VERSION_LAYOUT),
    ("contingency", FRONTIER_VERSION_CONTINGENCY),
];

/// A device-loss fallback attached to one frontier plan: a complete
/// alternative (graph, assignment) that avoids some device the primary
/// plan depends on, priced so the serve loop can slot it straight into
/// its grid. Synthesized at `--save-frontier` time (see
/// [`crate::search::synthesize_contingency`]) and persisted in v6
/// frontier manifests.
#[derive(Debug, Clone)]
pub struct ContingencyPlan {
    /// The fallback computation graph.
    pub graph: Graph,
    /// The fallback assignment (never touches the device it is a
    /// contingency for).
    pub assignment: Assignment,
    /// Oracle cost estimate of the fallback plan.
    pub cost: GraphCost,
}

fn cost_to_json(c: &GraphCost) -> Json {
    let mut o = Json::obj();
    o.set("time_ms", c.time_ms).set("energy_j", c.energy_j).set("freq_mhz", c.freq.0 as i64);
    o
}

fn cost_from_json(v: &Json) -> anyhow::Result<GraphCost> {
    let mhz = v.get("freq_mhz").and_then(Json::as_usize).unwrap_or(0);
    anyhow::ensure!(mhz <= u16::MAX as usize, "cost freq_mhz out of range");
    Ok(GraphCost {
        time_ms: v.req_f64("time_ms")?,
        energy_j: v.req_f64("energy_j")?,
        freq: FreqId(mhz as u16),
    })
}

/// Serialize a [`PlanFrontier`] as a versioned frontier manifest: every
/// entry is a complete single-plan document (the `--save-plan` format)
/// plus its probe weight and oracle cost estimate. Frontiers whose points
/// are all `batch = 1` emit the v2 format with no `batch` keys — byte
/// identical to the pre-batch-axis writer; any `batch > 1` point upgrades
/// the document to v3, where every plan entry carries its batch; any plan
/// placing a node off the GPU upgrades it to v4, where mixed entries
/// carry per-node `device` arrays; any plan computing a node in a
/// non-default layout upgrades it to v5, where layout-mixed entries carry
/// per-node `layout` arrays.
pub fn frontier_to_json(f: &PlanFrontier) -> Json {
    frontier_to_json_full(f, &[])
}

/// Like [`frontier_to_json`], with per-plan device-loss contingencies.
/// `contingencies` aligns by index with `f.points()` (shorter slices are
/// padded with `None`). Any present contingency upgrades the document to
/// v6; an all-`None` (or empty) slice emits byte-identically to
/// [`frontier_to_json`], so contingency-free callers never see a format
/// change.
pub fn frontier_to_json_full(f: &PlanFrontier, contingencies: &[Option<ContingencyPlan>]) -> Json {
    let batched = f.points().iter().any(|p| p.batch > 1);
    let placed = f.points().iter().any(|p| p.assignment.uses_non_gpu_device());
    let laid_out = f.points().iter().any(|p| p.assignment.uses_non_default_layout());
    let has_contingency = contingencies.iter().any(Option::is_some);
    let mut root = Json::obj();
    root.set(
        "version",
        if has_contingency {
            FRONTIER_VERSION_CONTINGENCY
        } else if laid_out {
            FRONTIER_VERSION_LAYOUT
        } else if placed {
            FRONTIER_VERSION_PLACED
        } else if batched {
            FRONTIER_VERSION_BATCHED
        } else {
            FRONTIER_VERSION
        },
    )
    .set("kind", "plan_frontier");
    root.set(
        "plans",
        Json::Arr(
            f.points()
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let mut o = plan_to_json(&p.graph, &p.assignment);
                    o.set("weight", p.weight).set("cost", cost_to_json(&p.cost));
                    if batched {
                        o.set("batch", p.batch as i64);
                    }
                    if let Some(c) = contingencies.get(i).and_then(Option::as_ref) {
                        let mut co = plan_to_json(&c.graph, &c.assignment);
                        co.set("cost", cost_to_json(&c.cost));
                        o.set("contingency", co);
                    }
                    o
                })
                .collect(),
        ),
    );
    root
}

/// Parse a frontier manifest — or, backward-compatibly, a pre-frontier
/// single-plan document, which loads as a one-point frontier (with a zero
/// cost estimate when the file carries none).
pub fn frontier_from_json(v: &Json, reg: &AlgorithmRegistry) -> anyhow::Result<PlanFrontier> {
    frontier_from_json_full(v, reg).map(|(f, _)| f)
}

/// Like [`frontier_from_json`], also surfacing each plan's device-loss
/// contingency (v6 manifests; `None` per plan for older files). The
/// returned contingency vector aligns by index with the returned
/// frontier's `points()` — surviving the same dominance prune and
/// fastest-first sort the points themselves go through.
pub fn frontier_from_json_full(
    v: &Json,
    reg: &AlgorithmRegistry,
) -> anyhow::Result<(PlanFrontier, Vec<Option<ContingencyPlan>>)> {
    let (entries, legacy): (Vec<&Json>, bool) = match v.get("plans") {
        Some(plans) => {
            // A present-but-malformed `plans` is a broken v2 manifest —
            // reject it rather than mis-parsing it as a legacy plan.
            let plans = plans
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("frontier manifest `plans` is not an array"))?;
            anyhow::ensure!(!plans.is_empty(), "frontier manifest holds no plans");
            (plans.iter().collect(), false)
        }
        // Legacy single-plan file: the document itself is the one entry.
        None => (vec![v], true),
    };
    // Versioned manifests must not smuggle in keys their declared version
    // predates: a v2 file with `layout` arrays (or a v5 file with
    // `contingency` plans) is corrupt or doctored, and honoring the key
    // would silently change what the historical format means.
    let version = if legacy { None } else { v.get("version").and_then(Json::as_i64) };
    let mut points = Vec::with_capacity(entries.len());
    let mut conts: Vec<Option<ContingencyPlan>> = Vec::with_capacity(entries.len());
    for (i, e) in entries.into_iter().enumerate() {
        if let Some(ver) = version {
            for (key, min) in VERSIONED_PLAN_KEYS {
                anyhow::ensure!(
                    ver >= min || e.get(key).is_none(),
                    "frontier plan {i}: `{key}` requires manifest version {min}+ (file declares version {ver})"
                );
            }
        }
        let (graph, assignment): (Graph, Assignment) =
            plan_from_json(e, reg).map_err(|err| anyhow::anyhow!("frontier plan {i}: {err}"))?;
        let cost = match e.get("cost") {
            Some(c) => {
                cost_from_json(c).map_err(|err| anyhow::anyhow!("frontier plan {i}: {err}"))?
            }
            // Only a legacy single-plan document may omit the estimate: a
            // one-point frontier never needs it. Zero-cost entries in a
            // multi-plan manifest would be collapsed by the dominance
            // prune, silently shrinking the frontier — reject instead.
            None if legacy => GraphCost::default(),
            None => anyhow::bail!("frontier plan {i} missing `cost`"),
        };
        let weight = e.get("weight").and_then(Json::as_f64).unwrap_or(1.0);
        // v3 operating points name their batch; v2/legacy entries are
        // batch-1 by definition.
        let batch = match e.get("batch") {
            Some(b) => {
                let b = b
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("frontier plan {i}: `batch` not an integer"))?;
                anyhow::ensure!(b >= 1, "frontier plan {i}: `batch` must be >= 1");
                b
            }
            None => 1,
        };
        let contingency = match e.get("contingency") {
            Some(c) => {
                let (cg, ca): (Graph, Assignment) = plan_from_json(c, reg)
                    .map_err(|err| anyhow::anyhow!("frontier plan {i} contingency: {err}"))?;
                let cc = match c.get("cost") {
                    Some(cc) => cost_from_json(cc)
                        .map_err(|err| anyhow::anyhow!("frontier plan {i} contingency: {err}"))?,
                    None => anyhow::bail!("frontier plan {i} contingency missing `cost`"),
                };
                Some(ContingencyPlan { graph: cg, assignment: ca, cost: cc })
            }
            None => None,
        };
        points.push(PlanPoint { graph, assignment, cost, weight, batch });
        conts.push(contingency);
    }
    // `from_points` dominance-prunes and re-sorts; re-align contingencies
    // with the survivors by their (cost, weight, batch) identity. Ties
    // consume file-order-first, matching the prune's earliest-kept rule.
    let keys: Vec<(u64, u64, u64, usize)> = points
        .iter()
        .map(|p| {
            (p.cost.time_ms.to_bits(), p.cost.energy_j.to_bits(), p.weight.to_bits(), p.batch)
        })
        .collect();
    let frontier = PlanFrontier::from_points(points);
    let mut used = vec![false; keys.len()];
    let aligned: Vec<Option<ContingencyPlan>> = frontier
        .points()
        .iter()
        .map(|p| {
            let key =
                (p.cost.time_ms.to_bits(), p.cost.energy_j.to_bits(), p.weight.to_bits(), p.batch);
            keys.iter()
                .enumerate()
                .find(|(j, k)| !used[*j] && **k == key)
                .and_then(|(j, _)| {
                    used[j] = true;
                    conts[j].take()
                })
        })
        .collect();
    Ok((frontier, aligned))
}

/// Like [`frontier_to_json`], with a free-form `note` annotating the
/// manifest's origin (e.g. `"feedback-research"` for surfaces re-searched
/// by the serve feedback loop). Loaders tolerate and ignore the key, and
/// an absent note keeps the document byte-identical to
/// [`frontier_to_json`]'s output.
pub fn frontier_to_json_noted(f: &PlanFrontier, note: Option<&str>) -> Json {
    let mut root = frontier_to_json(f);
    if let Some(n) = note {
        root.set("note", n);
    }
    root
}

/// Persist a frontier to `path` (versioned JSON, see [`frontier_to_json`]).
pub fn save_frontier(path: &Path, f: &PlanFrontier) -> anyhow::Result<()> {
    json::write_file(path, &frontier_to_json(f))
}

/// Persist a frontier with an origin note (see [`frontier_to_json_noted`]).
pub fn save_frontier_noted(path: &Path, f: &PlanFrontier, note: &str) -> anyhow::Result<()> {
    json::write_file(path, &frontier_to_json_noted(f, Some(note)))
}

/// Persist a frontier with per-plan device-loss contingencies (see
/// [`frontier_to_json_full`]). An all-`None` slice writes the same bytes
/// as [`save_frontier`].
pub fn save_frontier_with_contingencies(
    path: &Path,
    f: &PlanFrontier,
    contingencies: &[Option<ContingencyPlan>],
) -> anyhow::Result<()> {
    json::write_file(path, &frontier_to_json_full(f, contingencies))
}

/// Load a frontier from `path`; single-plan files load as a one-point
/// frontier (see [`frontier_from_json`]).
pub fn load_frontier(path: &Path, reg: &AlgorithmRegistry) -> anyhow::Result<PlanFrontier> {
    frontier_from_json(&json::read_file(path)?, reg)
}

/// Load a frontier plus its per-plan device-loss contingencies (see
/// [`frontier_from_json_full`]).
pub fn load_frontier_full(
    path: &Path,
    reg: &AlgorithmRegistry,
) -> anyhow::Result<(PlanFrontier, Vec<Option<ContingencyPlan>>)> {
    frontier_from_json_full(&json::read_file(path)?, reg)
}

/// Serve-side placement guard: every device the frontier's plans place
/// nodes on must be provided by the serving context. Returns the device
/// names used by some plan but missing from `provided` (empty when the
/// frontier is servable). A mixed-device plan priced against a
/// single-device cost grid would be silently mis-costed — callers should
/// reject instead.
pub fn unsupported_devices(f: &PlanFrontier, provided: &[String]) -> Vec<String> {
    let mut missing: Vec<String> = Vec::new();
    for p in f.points() {
        for d in p.assignment.devices_used() {
            let name = d.name();
            if !provided.iter().any(|s| s == name) && !missing.iter().any(|s| s == name) {
                missing.push(name.to_string());
            }
        }
    }
    missing
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            entries: vec![ArtifactEntry {
                key: "conv2d;st=1,1;pad=1,1;act=relu;b=0;res=0;1x3x8x8;4x3x3x3::direct".into(),
                file: "conv_a0.hlo.txt".into(),
                input_shapes: vec![vec![1, 3, 8, 8], vec![4, 3, 3, 3]],
                output_shapes: vec![vec![1, 4, 8, 8]],
                kernel: "pallas_direct".into(),
            }],
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = sample();
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.entries, m.entries);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("eadgo_manifest_test");
        let path = dir.join("manifest.json");
        sample().save(&path).unwrap();
        let back = Manifest::load(&path).unwrap();
        assert_eq!(back.entries.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_fields_rejected() {
        let j = crate::util::json::parse(r#"{"artifacts": [{"file": "x.hlo"}]}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    fn tiny_frontier() -> PlanFrontier {
        use crate::models::{self, ModelConfig};
        let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
        let reg = AlgorithmRegistry::new();
        let g = models::simple::build_cnn(cfg);
        let fast = Assignment::default_for(&g, &reg);
        let mut slow = fast.clone();
        slow.set_uniform_freq(FreqId(900));
        PlanFrontier::from_points(vec![
            PlanPoint {
                graph: g.clone(),
                assignment: fast,
                cost: GraphCost { time_ms: 1.0, energy_j: 250.0, freq: FreqId::NOMINAL },
                weight: 0.0,
                batch: 1,
            },
            PlanPoint {
                graph: g,
                assignment: slow,
                cost: GraphCost { time_ms: 2.5, energy_j: 125.0, freq: FreqId(900) },
                weight: 1.0,
                batch: 1,
            },
        ])
    }

    #[test]
    fn frontier_roundtrip_preserves_every_plan() {
        use crate::graph::canonical::graph_hash;
        let f = tiny_frontier();
        assert_eq!(f.len(), 2);
        let reg = AlgorithmRegistry::new();
        let back = frontier_from_json(&frontier_to_json(&f), &reg).unwrap();
        assert_eq!(back.len(), f.len());
        for (a, b) in f.points().iter().zip(back.points()) {
            assert_eq!(graph_hash(&a.graph), graph_hash(&b.graph));
            assert_eq!(a.assignment.distance(&b.assignment), 0);
            assert_eq!(a.cost.time_ms.to_bits(), b.cost.time_ms.to_bits());
            assert_eq!(a.cost.energy_j.to_bits(), b.cost.energy_j.to_bits());
            assert_eq!(a.cost.freq, b.cost.freq);
            assert_eq!(a.weight.to_bits(), b.weight.to_bits());
        }
    }

    #[test]
    fn frontier_file_roundtrip_and_legacy_plan_fallback() {
        use crate::models::{self, ModelConfig};
        let dir = std::env::temp_dir().join("eadgo_frontier_manifest_test");
        let reg = AlgorithmRegistry::new();

        let path = dir.join("frontier.json");
        let f = tiny_frontier();
        save_frontier(&path, &f).unwrap();
        let back = load_frontier(&path, &reg).unwrap();
        assert_eq!(back.len(), 2);

        // A pre-frontier single-plan file loads as a one-point frontier.
        let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
        let g = models::simple::build_cnn(cfg);
        let a = Assignment::default_for(&g, &reg);
        let legacy = dir.join("plan.json");
        crate::graph::serde::save_plan(&legacy, &g, &a).unwrap();
        let one = load_frontier(&legacy, &reg).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one.points()[0].assignment.distance(&a), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_frontier_manifest_rejected() {
        let j = crate::util::json::parse(r#"{"version": 2, "plans": []}"#).unwrap();
        assert!(frontier_from_json(&j, &AlgorithmRegistry::new()).is_err());
    }

    #[test]
    fn v2_entry_without_cost_rejected() {
        // Build a v2 manifest whose entries lack the `cost` field (e.g.
        // hand-assembled from --save-plan files): must error, not load
        // zero-cost plans that the dominance prune would then collapse.
        use crate::models::{self, ModelConfig};
        let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
        let g = models::simple::build_cnn(cfg);
        let a = Assignment::default_for(&g, &AlgorithmRegistry::new());
        let plan = crate::graph::serde::plan_to_json(&g, &a);
        let mut root = crate::util::json::Json::obj();
        root.set("version", 2i64);
        root.set("plans", crate::util::json::Json::Arr(vec![plan.clone(), plan]));
        let err = frontier_from_json(&root, &AlgorithmRegistry::new()).unwrap_err().to_string();
        assert!(err.contains("missing `cost`"), "{err}");
    }

    #[test]
    fn malformed_plans_key_rejected_not_misparsed() {
        // A present-but-non-array `plans` is a broken v2 manifest, not a
        // legacy single-plan file.
        let j = crate::util::json::parse(r#"{"version": 2, "plans": {"oops": 1}}"#).unwrap();
        let err = frontier_from_json(&j, &AlgorithmRegistry::new()).unwrap_err().to_string();
        assert!(err.contains("not an array"), "{err}");
    }

    fn batched_frontier() -> PlanFrontier {
        use crate::models::{self, ModelConfig};
        let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
        let reg = AlgorithmRegistry::new();
        let g = models::simple::build_cnn(cfg);
        let a = Assignment::default_for(&g, &reg);
        let g8 = g.rebatch(8).unwrap();
        PlanFrontier::from_points(vec![
            PlanPoint {
                graph: g,
                assignment: a.clone(),
                cost: GraphCost { time_ms: 1.0, energy_j: 250.0, freq: FreqId::NOMINAL },
                weight: 0.0,
                batch: 1,
            },
            PlanPoint {
                graph: g8,
                assignment: a,
                cost: GraphCost { time_ms: 2.5, energy_j: 800.0, freq: FreqId::NOMINAL },
                weight: 1.0,
                batch: 8, // 100 mJ/request
            },
        ])
    }

    #[test]
    fn batch1_frontier_serializes_as_v2_without_batch_keys() {
        // Format stability: the batch axis must be invisible for pure
        // batch-1 frontiers — same version, no extra keys, so pre-batch
        // tooling (and the byte-diff CI jobs) see identical documents.
        let j = frontier_to_json(&tiny_frontier());
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(2));
        let plans = j.get("plans").and_then(Json::as_arr).unwrap();
        assert!(plans.iter().all(|p| p.get("batch").is_none()));
    }

    #[test]
    fn batched_frontier_roundtrips_as_v3_with_per_plan_batch() {
        use crate::graph::canonical::graph_hash;
        let f = batched_frontier();
        assert_eq!(f.len(), 2);
        let j = frontier_to_json(&f);
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(3));
        let plans = j.get("plans").and_then(Json::as_arr).unwrap();
        assert!(plans.iter().all(|p| p.get("batch").is_some()));
        let back = frontier_from_json(&j, &AlgorithmRegistry::new()).unwrap();
        assert_eq!(back.len(), f.len());
        for (a, b) in f.points().iter().zip(back.points()) {
            assert_eq!(a.batch, b.batch, "batch annotation changed");
            assert_eq!(graph_hash(&a.graph), graph_hash(&b.graph));
            assert_eq!(a.cost.energy_j.to_bits(), b.cost.energy_j.to_bits());
        }
    }

    #[test]
    fn placed_frontier_roundtrips_as_v4_with_device_arrays() {
        use crate::energysim::DeviceId;
        use crate::graph::canonical::graph_hash;
        use crate::graph::OpKind;
        use crate::models::{self, ModelConfig};
        let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
        let reg = AlgorithmRegistry::new();
        let g = models::simple::build_cnn(cfg);
        let gpu = Assignment::default_for(&g, &reg);
        let conv = g.nodes().find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. })).unwrap().0;
        let mut mixed = gpu.clone();
        mixed.set_freq(conv, FreqId::on(DeviceId::DLA, 0));
        assert!(mixed.uses_non_gpu_device());
        let f = PlanFrontier::from_points(vec![
            PlanPoint {
                graph: g.clone(),
                assignment: gpu,
                cost: GraphCost { time_ms: 1.0, energy_j: 250.0, freq: FreqId::NOMINAL },
                weight: 0.0,
                batch: 1,
            },
            PlanPoint {
                graph: g,
                assignment: mixed,
                cost: GraphCost { time_ms: 2.0, energy_j: 90.0, freq: FreqId::NOMINAL },
                weight: 1.0,
                batch: 1,
            },
        ]);
        assert_eq!(f.len(), 2);
        let j = frontier_to_json(&f);
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(4));
        let plans = j.get("plans").and_then(Json::as_arr).unwrap();
        // Only the mixed plan carries a device array; the all-GPU entry
        // stays in the legacy shape.
        assert!(plans[0].get("device").is_none());
        assert!(plans[1].get("device").is_some());
        let back = frontier_from_json(&j, &AlgorithmRegistry::new()).unwrap();
        assert_eq!(back.len(), f.len());
        for (a, b) in f.points().iter().zip(back.points()) {
            assert_eq!(graph_hash(&a.graph), graph_hash(&b.graph));
            assert_eq!(a.assignment.distance(&b.assignment), 0);
        }
        assert_eq!(back.points()[1].assignment.freq(conv), FreqId::on(DeviceId::DLA, 0));
        // Single-device frontiers never pick up the new version.
        assert_eq!(
            frontier_to_json(&tiny_frontier()).get("version").and_then(Json::as_usize),
            Some(2)
        );
    }

    #[test]
    fn laid_out_frontier_roundtrips_as_v5_with_layout_arrays() {
        use crate::energysim::Layout;
        use crate::graph::canonical::graph_hash;
        use crate::graph::OpKind;
        use crate::models::{self, ModelConfig};
        let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
        let reg = AlgorithmRegistry::new();
        let g = models::simple::build_cnn(cfg);
        let nchw = Assignment::default_for(&g, &reg);
        let conv = g.nodes().find(|(_, n)| matches!(n.op, OpKind::Conv2d { .. })).unwrap().0;
        let mut mixed = nchw.clone();
        mixed.set_freq(conv, mixed.freq(conv).with_layout(Layout::NHWC));
        assert!(mixed.uses_non_default_layout());
        let f = PlanFrontier::from_points(vec![
            PlanPoint {
                graph: g.clone(),
                assignment: nchw,
                cost: GraphCost { time_ms: 1.0, energy_j: 250.0, freq: FreqId::NOMINAL },
                weight: 0.0,
                batch: 1,
            },
            PlanPoint {
                graph: g,
                assignment: mixed,
                cost: GraphCost { time_ms: 1.0, energy_j: 200.0, freq: FreqId::NOMINAL },
                weight: 1.0,
                batch: 1,
            },
        ]);
        assert_eq!(f.len(), 2);
        let j = frontier_to_json(&f);
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(5));
        let plans = j.get("plans").and_then(Json::as_arr).unwrap();
        // Only the layout-mixed plan carries a layout array; the all-NCHW
        // entry stays in the legacy shape.
        assert!(plans[0].get("layout").is_none());
        assert!(plans[1].get("layout").is_some());
        let back = frontier_from_json(&j, &AlgorithmRegistry::new()).unwrap();
        assert_eq!(back.len(), f.len());
        for (a, b) in f.points().iter().zip(back.points()) {
            assert_eq!(graph_hash(&a.graph), graph_hash(&b.graph));
            assert_eq!(a.assignment.distance(&b.assignment), 0);
        }
        assert_eq!(back.points()[1].assignment.freq(conv).layout(), Layout::NHWC);
        // Layout-free frontiers never pick up the new version.
        assert_eq!(
            frontier_to_json(&tiny_frontier()).get("version").and_then(Json::as_usize),
            Some(2)
        );
    }

    #[test]
    fn noted_frontier_roundtrips_and_absent_note_is_byte_stable() {
        let f = tiny_frontier();
        // The note rides along and the loader ignores it.
        let j = frontier_to_json_noted(&f, Some("feedback-research"));
        assert_eq!(j.get("note").and_then(Json::as_str), Some("feedback-research"));
        let back = frontier_from_json(&j, &AlgorithmRegistry::new()).unwrap();
        assert_eq!(back.len(), f.len());
        // No note => byte-identical to the plain writer (format stability).
        assert_eq!(
            frontier_to_json_noted(&f, None).to_string_compact(),
            frontier_to_json(&f).to_string_compact()
        );
    }

    #[test]
    fn contingent_frontier_roundtrips_as_v6() {
        use crate::graph::canonical::graph_hash;
        let f = tiny_frontier();
        // Fallback for the slow plan: the fast plan's (graph, assignment)
        // repriced — any complete plan document works as a contingency.
        let fallback = ContingencyPlan {
            graph: f.points()[0].graph.clone(),
            assignment: f.points()[0].assignment.clone(),
            cost: GraphCost { time_ms: 1.5, energy_j: 300.0, freq: FreqId::NOMINAL },
        };
        let conts = vec![None, Some(fallback.clone())];
        let j = frontier_to_json_full(&f, &conts);
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(6));
        let plans = j.get("plans").and_then(Json::as_arr).unwrap();
        assert!(plans[0].get("contingency").is_none());
        assert!(plans[1].get("contingency").is_some());
        let (back, back_conts) = frontier_from_json_full(&j, &AlgorithmRegistry::new()).unwrap();
        assert_eq!(back.len(), f.len());
        assert_eq!(back_conts.len(), back.len());
        assert!(back_conts[0].is_none());
        let bc = back_conts[1].as_ref().expect("slow plan's contingency survived the round-trip");
        assert_eq!(graph_hash(&bc.graph), graph_hash(&fallback.graph));
        assert_eq!(bc.assignment.distance(&fallback.assignment), 0);
        assert_eq!(bc.cost.energy_j.to_bits(), fallback.cost.energy_j.to_bits());
        // The plain loader still works on v6 files, just without fallbacks.
        assert_eq!(frontier_from_json(&j, &AlgorithmRegistry::new()).unwrap().len(), 2);
    }

    #[test]
    fn contingency_free_full_writer_is_byte_stable() {
        // Format stability: all-None contingencies must be invisible —
        // same version, same bytes — so fault-unaware pipelines and the
        // byte-diff CI jobs never see a format change.
        let f = tiny_frontier();
        assert_eq!(
            frontier_to_json_full(&f, &[None, None]).to_string_compact(),
            frontier_to_json(&f).to_string_compact()
        );
        assert_eq!(
            frontier_to_json_full(&f, &[]).to_string_compact(),
            frontier_to_json(&f).to_string_compact()
        );
    }

    #[test]
    fn contingency_on_pre_v6_file_rejected() {
        // Downgrade a v6 document's version stamp while keeping its
        // contingency entries: corrupt, must be a typed load error.
        let f = tiny_frontier();
        let fallback = ContingencyPlan {
            graph: f.points()[0].graph.clone(),
            assignment: f.points()[0].assignment.clone(),
            cost: GraphCost { time_ms: 1.5, energy_j: 300.0, freq: FreqId::NOMINAL },
        };
        let s = frontier_to_json_full(&f, &[None, Some(fallback)]).to_string_compact();
        assert!(s.contains("\"version\":6"), "fixture lost its version stamp: {s}");
        let j = crate::util::json::parse(&s.replace("\"version\":6", "\"version\":5")).unwrap();
        let err = frontier_from_json(&j, &AlgorithmRegistry::new()).unwrap_err().to_string();
        assert!(err.contains("contingency") && err.contains("version"), "{err}");
    }

    #[test]
    fn bad_batch_values_rejected() {
        // Corrupt one plan's batch annotation to 0: must error, not load a
        // divide-by-zero operating point.
        let s = frontier_to_json(&batched_frontier()).to_string_compact();
        assert!(s.contains("\"batch\":8"), "fixture lost its batch annotation: {s}");
        let j = crate::util::json::parse(&s.replace("\"batch\":8", "\"batch\":0")).unwrap();
        let err = frontier_from_json(&j, &AlgorithmRegistry::new()).unwrap_err().to_string();
        assert!(err.contains("batch"), "{err}");
    }
}
