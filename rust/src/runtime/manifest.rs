//! Artifact manifest: the contract between `python/compile/aot.py` (writer)
//! and the rust runtime (reader). Lives at `artifacts/manifest.json`.

use crate::util::json::{self, Json};
use std::path::Path;

/// One AOT artifact: an HLO-text file plus its I/O signature.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Lookup key. Node kernels use `<node signature>::<algorithm>`;
    /// whole-model artifacts use plain names (`model_fwd`).
    pub key: String,
    /// File name relative to the artifacts directory.
    pub file: String,
    /// Expected input tensor shapes, in call order.
    pub input_shapes: Vec<Vec<usize>>,
    /// Produced output tensor shapes.
    pub output_shapes: Vec<Vec<usize>>,
    /// Which kernel implementation the artifact embeds ("pallas_direct",
    /// "pallas_im2col", "pallas_winograd", "jnp", ...). Informational.
    pub kernel: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

fn shapes_to_json(shapes: &[Vec<usize>]) -> Json {
    Json::Arr(
        shapes
            .iter()
            .map(|s| Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect()))
            .collect(),
    )
}

fn shapes_from_json(v: &Json, what: &str) -> anyhow::Result<Vec<Vec<usize>>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what} not an array"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow::anyhow!("{what} element not an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("{what} dim not a number")))
                .collect()
        })
        .collect()
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("version", 1i64);
        root.set(
            "artifacts",
            Json::Arr(
                self.entries
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.set("key", e.key.as_str())
                            .set("file", e.file.as_str())
                            .set("inputs", shapes_to_json(&e.input_shapes))
                            .set("outputs", shapes_to_json(&e.output_shapes))
                            .set("kernel", e.kernel.as_str());
                        o
                    })
                    .collect(),
            ),
        );
        root
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Manifest> {
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing `artifacts`"))?;
        let mut entries = Vec::with_capacity(arts.len());
        for a in arts {
            entries.push(ArtifactEntry {
                key: a.req_str("key")?.to_string(),
                file: a.req_str("file")?.to_string(),
                input_shapes: shapes_from_json(
                    a.get("inputs").unwrap_or(&Json::Null),
                    "inputs",
                )?,
                output_shapes: shapes_from_json(
                    a.get("outputs").unwrap_or(&Json::Null),
                    "outputs",
                )?,
                kernel: a
                    .get("kernel")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
            });
        }
        Ok(Manifest { entries })
    }

    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        Manifest::from_json(&json::read_file(path)?)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        json::write_file(path, &self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            entries: vec![ArtifactEntry {
                key: "conv2d;st=1,1;pad=1,1;act=relu;b=0;res=0;1x3x8x8;4x3x3x3::direct".into(),
                file: "conv_a0.hlo.txt".into(),
                input_shapes: vec![vec![1, 3, 8, 8], vec![4, 3, 3, 3]],
                output_shapes: vec![vec![1, 4, 8, 8]],
                kernel: "pallas_direct".into(),
            }],
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = sample();
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.entries, m.entries);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("eadgo_manifest_test");
        let path = dir.join("manifest.json");
        sample().save(&path).unwrap();
        let back = Manifest::load(&path).unwrap();
        assert_eq!(back.entries.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_fields_rejected() {
        let j = crate::util::json::parse(r#"{"artifacts": [{"file": "x.hlo"}]}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }
}
