//! Regeneration of every table in the paper's evaluation (§4).
//!
//! Each `tableN` function returns both the rendered [`Table`] and the raw
//! numbers so benches and tests can assert on the *shape* of the results
//! (who wins, by what factor) rather than string output.

use super::{describe_freqs, f3, Table};
use crate::algo::{Algorithm, Assignment};
use crate::cost::{CostFunction, GraphCost};
use crate::energysim::{node_work, EnergyModel, FreqId, SimCost, Work};
use crate::graph::{Graph, OpKind};
use crate::models::{self, ModelConfig};
use crate::search::{
    optimize, DvfsMode, OptimizeResult, OptimizerContext, PlanFrontier, SearchConfig, SearchStats,
};

/// Experiment-wide knobs.
#[derive(Debug, Clone, Copy)]
pub struct ExperimentConfig {
    /// Seed for the sim provider and measurement noise.
    pub seed: u64,
    /// Model scale used across every table.
    pub model_cfg: ModelConfig,
    /// Search budget knobs.
    pub search: SearchKnobs,
}

/// The search-budget subset of [`ExperimentConfig`].
#[derive(Debug, Clone, Copy)]
pub struct SearchKnobs {
    /// Relaxation factor of the outer search.
    pub alpha: f64,
    /// Hard cap on dequeued states.
    pub max_dequeues: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 7,
            // Full published scale: the SimV100 provider is analytic (it
            // never executes tensors), so paper-scale shapes cost nothing
            // and keep nodes compute-bound as on the real V100 — reduced
            // shapes would be launch-overhead-dominated and flatten the
            // algorithm differences the paper measures.
            model_cfg: ModelConfig { batch: 1, resolution: 224, width_div: 1, classes: 1000 },
            search: SearchKnobs { alpha: 1.05, max_dequeues: 400 },
        }
    }
}

impl ExperimentConfig {
    /// Fast profile for CI (`--quick`).
    pub fn quick() -> ExperimentConfig {
        ExperimentConfig {
            search: SearchKnobs { alpha: 1.05, max_dequeues: 60 },
            ..Default::default()
        }
    }

    /// Expand into a full [`SearchConfig`].
    pub fn search_config(&self) -> SearchConfig {
        SearchConfig {
            alpha: self.search.alpha,
            max_dequeues: self.search.max_dequeues,
            ..Default::default()
        }
    }

    fn ctx(&self) -> OptimizerContext {
        OptimizerContext::new(
            crate::subst::RuleSet::standard(),
            crate::cost::CostDb::new(),
            Box::new(crate::profiler::SimV100Provider::new(self.seed)),
        )
    }

    fn model(&self) -> EnergyModel {
        EnergyModel::v100(self.seed)
    }
}

/// "Actually measure" a (G, A) on the simulated device: whole-graph run with
/// dispatch overheads + idle gaps (the paper's nvidia-smi measurement step).
/// Each node executes at its plan frequency (all-nominal for DVFS-off plans).
pub fn measure_actual(g: &Graph, a: &Assignment, model: &EnergyModel) -> SimCost {
    let shapes = g.infer_shapes().expect("invalid graph");
    let mut nodes: Vec<(String, Work, Algorithm, FreqId)> = Vec::new();
    for (id, node) in g.nodes() {
        if node.op.is_constant_space() || matches!(node.op, OpKind::Input { .. }) {
            continue;
        }
        let in_shapes: Vec<_> = node
            .inputs
            .iter()
            .map(|p| shapes[p.node.0][p.port].clone())
            .collect();
        let sig = node.op.signature(&in_shapes);
        let w = node_work(&node.op, &in_shapes, &shapes[id.0]);
        nodes.push((sig, w, a.get(id).unwrap_or(Algorithm::Passthrough), a.freq(id)));
    }
    model.graph_run(&nodes)
}

// ---------------------------------------------------------------------------
// Table 1 — costs of graph nodes under different algorithms
// ---------------------------------------------------------------------------

/// Raw Table-1 data: per conv config, per algorithm, the simulated profile.
pub struct Table1Data {
    /// (node label, Vec<(algorithm, cost)>)
    pub nodes: Vec<(String, Vec<(Algorithm, SimCost)>)>,
}

/// Table 1: per-node costs under each applicable algorithm.
pub fn table1(cfg: &ExperimentConfig) -> (Table, Table1Data) {
    let model = cfg.model();
    // Three convolution configurations mirroring the paper's: conv1 is
    // bandwidth-leaning (Winograd inapplicable: stride 2), conv2 is tiny
    // (1x1; Winograd inapplicable), conv3 is a large 3x3 stride-1 where all
    // three algorithms apply.
    let configs: Vec<(&str, OpKind, Vec<Vec<usize>>)> = vec![
        (
            "conv1",
            conv_op((2, 2), (1, 1)),
            vec![vec![1, 64, 56, 56], vec![64, 64, 3, 3]],
        ),
        (
            "conv2",
            conv_op((1, 1), (0, 0)),
            vec![vec![1, 64, 56, 56], vec![256, 64, 1, 1]],
        ),
        (
            "conv3",
            conv_op((1, 1), (1, 1)),
            vec![vec![1, 128, 28, 28], vec![128, 128, 3, 3]],
        ),
    ];
    let reg = crate::algo::AlgorithmRegistry::new();
    let mut data = Table1Data { nodes: Vec::new() };
    let mut t = Table::new(
        "Table 1: costs of DNN graph nodes under different algorithms (sim-V100)",
        &["node", "algo", "time_ms", "power_w", "energy_j/1k", "vs A time", "vs A energy"],
    );
    for (label, op, in_shapes) in configs {
        let out_shapes = op.infer_shapes(&in_shapes).expect("table1 config invalid");
        let sig = op.signature(&in_shapes);
        let work = node_work(&op, &in_shapes, &out_shapes);
        let algos = reg.applicable(&op, &in_shapes);
        let costs: Vec<(Algorithm, SimCost)> = algos
            .iter()
            .map(|&a| (a, model.measured_cost(&sig, &work, a)))
            .collect();
        let base = costs[0].1; // algorithm A = im2col
        for (a, c) in &costs {
            t.row(vec![
                label.to_string(),
                format!("{} ({})", a.letter(), a.name()),
                f3(c.time_ms),
                f3(c.power_w),
                f3(c.energy_j()),
                format!("{:.2}x", c.time_ms / base.time_ms),
                format!("{:.2}x", c.energy_j() / base.energy_j()),
            ]);
        }
        data.nodes.push((label.to_string(), costs));
    }
    (t, data)
}

fn conv_op(stride: (usize, usize), pad: (usize, usize)) -> OpKind {
    OpKind::Conv2d {
        stride,
        pad,
        act: crate::graph::Activation::Relu,
        has_bias: false,
        has_residual: false,
    }
}

// ---------------------------------------------------------------------------
// Table 2 — accuracy of the cost model (SqueezeNet)
// ---------------------------------------------------------------------------

/// Raw Table-2 data: estimated vs actual costs along a search trajectory.
pub struct Table2Data {
    /// Per graph: (estimated, actual).
    pub graphs: Vec<(GraphCost, SimCost)>,
    /// Mean absolute percentage error of the time estimates.
    pub time_mape: f64,
    /// Mean absolute percentage error of the power estimates.
    pub power_mape: f64,
    /// Mean absolute percentage error of the energy estimates.
    pub energy_mape: f64,
    /// Kendall rank correlation on energy (order preservation, the paper's
    /// headline claim for the cost model).
    pub energy_tau: f64,
}

/// Table 2: accuracy of the cost model on SqueezeNet search snapshots.
pub fn table2(cfg: &ExperimentConfig) -> (Table, Table2Data) {
    let g0 = models::squeezenet::build(cfg.model_cfg);
    let ctx = cfg.ctx();
    let model = cfg.model();

    // Collect 8 snapshots along the energy-objective search, like the
    // paper's "several graphs from the search process of SqueezeNet":
    // origin + progressively better (G, A) pairs.
    let snapshots = search_snapshots(&g0, &ctx, &CostFunction::Energy, &cfg.search_config(), 8);

    let mut t = Table::new(
        "Table 2: accuracy of cost model (SqueezeNet, sim-V100)",
        &["graph", "est time", "act time", "est pwr", "act pwr", "est enrg", "act enrg"],
    );
    let mut graphs = Vec::new();
    for (i, (g, a)) in snapshots.iter().enumerate() {
        let (table, _) = ctx.table_for(g).expect("profile");
        let est = table.eval(a);
        let act = measure_actual(g, a, &model);
        t.row(vec![
            format!("graph{}", i + 1),
            f3(est.time_ms),
            f3(act.time_ms),
            f3(est.power_w()),
            f3(act.power_w),
            f3(est.energy_j),
            f3(act.energy_j()),
        ]);
        graphs.push((est, act));
    }
    let est_t: Vec<f64> = graphs.iter().map(|(e, _)| e.time_ms).collect();
    let act_t: Vec<f64> = graphs.iter().map(|(_, a)| a.time_ms).collect();
    let est_p: Vec<f64> = graphs.iter().map(|(e, _)| e.power_w()).collect();
    let act_p: Vec<f64> = graphs.iter().map(|(_, a)| a.power_w).collect();
    let est_e: Vec<f64> = graphs.iter().map(|(e, _)| e.energy_j).collect();
    let act_e: Vec<f64> = graphs.iter().map(|(_, a)| a.energy_j()).collect();
    let data = Table2Data {
        time_mape: crate::util::stats::mape(&act_t, &est_t),
        power_mape: crate::util::stats::mape(&act_p, &est_p),
        energy_mape: crate::util::stats::mape(&act_e, &est_e),
        energy_tau: if graphs.len() >= 2 {
            crate::util::stats::kendall_tau(&est_e, &act_e)
        } else {
            1.0
        },
        graphs,
    };
    (t, data)
}

/// Run the optimizer once and sample `n` evenly-spaced points from its
/// best-so-far trajectory — genuine "graphs from the search process" in
/// improving order, like the paper's graph1..graph8.
fn search_snapshots(
    g0: &Graph,
    ctx: &OptimizerContext,
    objective: &CostFunction,
    cfg: &SearchConfig,
    n: usize,
) -> Vec<(Graph, Assignment)> {
    let baseline =
        crate::search::evaluate_baseline(g0, &ctx.oracle).expect("baseline evaluation failed");
    let res =
        crate::search::outer_search(g0, ctx, objective, cfg, &baseline).expect("search failed");
    let traj = res.trajectory;
    if traj.len() <= n {
        return traj.into_iter().map(|(g, a, _)| (g, a)).collect();
    }
    // Evenly sample, always keeping the first (origin) and last (best).
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let idx = i * (traj.len() - 1) / (n - 1);
        out.push((traj[idx].0.clone(), traj[idx].1.clone()));
    }
    out
}

// ---------------------------------------------------------------------------
// Table 3 — various goals on 3 CNN graphs
// ---------------------------------------------------------------------------

/// One (model, variant) measurement of Table 3.
#[derive(Debug, Clone)]
pub struct Table3Row {
    /// Model name.
    pub model: String,
    /// Optimization variant label.
    pub variant: String,
    /// Simulated whole-graph measurement of the variant's plan.
    pub cost: SimCost,
}

/// Raw Table-3 data: every (model, variant) measurement.
pub struct Table3Data {
    /// All rows, table order.
    pub rows: Vec<Table3Row>,
}

impl Table3Data {
    /// Look up one (model, variant) row.
    pub fn get(&self, model: &str, variant: &str) -> Option<&Table3Row> {
        self.rows.iter().find(|r| r.model == model && r.variant == variant)
    }
}

/// Table 3: various optimization goals on three CNN graphs.
pub fn table3(cfg: &ExperimentConfig) -> (Table, Table3Data) {
    let mut t = Table::new(
        "Table 3: various goals on 3 CNN graphs (sim-V100)",
        &["model", "variant", "time_ms", "power_w", "energy_j/1k", "freq"],
    );
    let mut data = Table3Data { rows: Vec::new() };
    let model = cfg.model();
    for name in ["squeezenet", "inception", "resnet"] {
        let g0 = models::by_name(name, cfg.model_cfg).unwrap();
        let scfg = cfg.search_config();

        let mut push = |variant: &str, g: &Graph, a: &Assignment, data: &mut Table3Data| {
            let c = measure_actual(g, a, &model);
            t.row(vec![
                name.to_string(),
                variant.to_string(),
                f3(c.time_ms),
                f3(c.power_w),
                f3(c.energy_j()),
                describe_freqs(a),
            ]);
            data.rows.push(Table3Row {
                model: name.to_string(),
                variant: variant.to_string(),
                cost: c,
            });
        };

        // Origin: no optimization at all.
        {
            let ctx = cfg.ctx();
            let res = optimize(
                &g0,
                &ctx,
                &CostFunction::Time,
                &SearchConfig { enable_outer: false, enable_inner: false, ..scfg.clone() },
            )
            .unwrap();
            push("origin", &res.graph, &res.assignment, &mut data);
        }
        // MetaFlow best time: outer search only, time objective, default algos.
        {
            let ctx = cfg.ctx();
            let res = optimize(
                &g0,
                &ctx,
                &CostFunction::Time,
                &SearchConfig { enable_inner: false, ..scfg.clone() },
            )
            .unwrap();
            push("metaflow_best_time", &res.graph, &res.assignment, &mut data);
        }
        // Ours.
        for (variant, objective) in [
            ("best_time", CostFunction::Time),
            ("best_energy", CostFunction::Energy),
            ("best_power", CostFunction::Power),
            ("0.5power+0.5energy", CostFunction::power_energy(0.5)),
        ] {
            let ctx = cfg.ctx();
            let res = optimize(&g0, &ctx, &objective, &scfg).unwrap();
            push(variant, &res.graph, &res.assignment, &mut data);
        }
        // Ours + the DVFS frequency axis (beyond the paper: the joint
        // (G, A, f) search of arXiv:1905.11012 / PolyThrottle).
        for (variant, dvfs) in [
            ("best_energy@per-graph", DvfsMode::PerGraph),
            ("best_energy@per-node", DvfsMode::PerNode),
        ] {
            let ctx = cfg.ctx();
            let res = optimize(
                &g0,
                &ctx,
                &CostFunction::Energy,
                &SearchConfig { dvfs, ..scfg.clone() },
            )
            .unwrap();
            push(variant, &res.graph, &res.assignment, &mut data);
        }
    }
    (t, data)
}

// ---------------------------------------------------------------------------
// Table 4 — balance between time and energy (SqueezeNet)
// ---------------------------------------------------------------------------

/// Raw Table-4 data: the time/energy balance sweep.
pub struct Table4Data {
    /// (label, weight-on-time, cost)
    pub rows: Vec<(String, f64, SimCost)>,
}

/// Table 4: balance between time and energy on SqueezeNet.
pub fn table4(cfg: &ExperimentConfig) -> (Table, Table4Data) {
    let g0 = models::squeezenet::build(cfg.model_cfg);
    let model = cfg.model();
    let scfg = cfg.search_config();
    let mut t = Table::new(
        "Table 4: balance between time and energy (SqueezeNet, sim-V100)",
        &["objective", "time_ms", "power_w", "energy_j/1k", "freq"],
    );
    let mut data = Table4Data { rows: Vec::new() };
    // paper sweeps w (weight on TIME) from 1 to 0
    for wt in [1.0, 0.8, 0.6, 0.4, 0.2, 0.0] {
        let label = match wt {
            w if w == 1.0 => "best_time".to_string(),
            w if w == 0.0 => "best_energy".to_string(),
            w => format!("{:.1}time+{:.1}energy", w, 1.0 - w),
        };
        // our CostFunction::linear takes weight on ENERGY
        let objective = CostFunction::linear(1.0 - wt);
        let ctx = cfg.ctx();
        let res: OptimizeResult = optimize(&g0, &ctx, &objective, &scfg).unwrap();
        let c = measure_actual(&res.graph, &res.assignment, &model);
        t.row(vec![
            label.clone(),
            f3(c.time_ms),
            f3(c.power_w),
            f3(c.energy_j()),
            describe_freqs(&res.assignment),
        ]);
        data.rows.push((label, wt, c));
    }
    (t, data)
}

// ---------------------------------------------------------------------------
// Pareto plan frontiers (beyond the paper: the serve-time trade-off)
// ---------------------------------------------------------------------------

/// Render a [`PlanFrontier`] as an aligned table: one row per plan,
/// fastest-first, with the probe weight, the oracle cost columns, the DVFS
/// summary, and the plan's role on the frontier. Pass the origin cost to
/// append an `origin` reference row.
pub fn frontier_table(f: &PlanFrontier, original: Option<&GraphCost>) -> Table {
    let mut t = Table::new(
        "Pareto operating-point frontier (batch latency vs energy/request, fastest-first)",
        &["plan", "w_energy", "batch", "time_ms", "power_w", "energy_j/1k", "e_j/req", "freq",
          "role"],
    );
    let n = f.len();
    for (i, p) in f.points().iter().enumerate() {
        let role = if n == 1 {
            "only"
        } else if i == 0 {
            "latency-optimal"
        } else if i + 1 == n {
            "energy-optimal"
        } else {
            "balance"
        };
        t.row(vec![
            format!("p{i}"),
            format!("{:.2}", p.weight),
            p.batch.to_string(),
            f3(p.cost.time_ms),
            f3(p.cost.power_w()),
            f3(p.cost.energy_j),
            f3(p.energy_per_request()),
            describe_freqs(&p.assignment),
            role.to_string(),
        ]);
    }
    if let Some(o) = original {
        t.row(vec![
            "origin".to_string(),
            "-".to_string(),
            "1".to_string(),
            f3(o.time_ms),
            f3(o.power_w()),
            f3(o.energy_j),
            f3(o.energy_j),
            "nominal".to_string(),
            "unoptimized".to_string(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Per-rule substitution statistics (the delta engine's accounting)
// ---------------------------------------------------------------------------

/// Render a search run's per-rule statistics: rewrite sites found, deltas
/// accepted into the α-band queue, and the net objective improvement
/// attributed to each rule's candidates (normalized objective units — a
/// gain of 0.05 means the rule's wins cut 5% of the origin objective).
/// Wired into `eadgo optimize` output and the ablation bench.
pub fn rule_stats_table(stats: &SearchStats) -> Table {
    let mut t = Table::new(
        "Per-rule substitution statistics (sites found / deltas accepted / objective gain)",
        &["rule", "sites", "enqueued", "objective gain"],
    );
    for r in &stats.rule_stats {
        t.row(vec![
            r.name.clone(),
            r.sites.to_string(),
            r.enqueued.to_string(),
            format!("{:.4}", r.objective_gain),
        ]);
    }
    t
}

/// Render a search run's inner-search economy: warm vs cold starts,
/// dirty-cone vs total node decisions, and the per-row argmin memo hit
/// rate — the instrumentation behind the incremental inner search
/// (`SearchConfig::incremental_inner`). Wired into `eadgo optimize`
/// output and the ablation bench alongside [`rule_stats_table`].
pub fn inner_stats_table(stats: &SearchStats) -> Table {
    let mut t = Table::new(
        "Inner-search economy (warm starts / dirty-cone sweeps / argmin memo)",
        &["metric", "value", "share"],
    );
    let starts = stats.inner_warm + stats.inner_cold;
    t.row(vec![
        "warm starts".into(),
        stats.inner_warm.to_string(),
        if starts > 0 {
            format!("{:.1}%", 100.0 * stats.inner_warm as f64 / starts as f64)
        } else {
            "-".into()
        },
    ]);
    t.row(vec!["cold starts".into(), stats.inner_cold.to_string(), "-".into()]);
    t.row(vec![
        "nodes re-derived".into(),
        format!("{}/{}", stats.inner_swept, stats.inner_nodes),
        format!("carry rate {:.1}%", 100.0 * stats.inner_carry_rate()),
    ]);
    t.row(vec![
        "argmin memo".into(),
        format!("{} hits / {} misses", stats.argmin_hits, stats.argmin_misses),
        format!("hit rate {:.1}%", 100.0 * stats.argmin_hit_rate()),
    ]);
    t.row(vec!["option evaluations".into(), stats.inner_evals.to_string(), "-".into()]);
    t
}

// ---------------------------------------------------------------------------
// Table 5 — contribution of the inner search (SqueezeNet, energy objective)
// ---------------------------------------------------------------------------

/// Raw Table-5 data: the two-level ablation.
pub struct Table5Data {
    /// No optimization at all.
    pub origin: SimCost,
    /// Outer (graph) search only.
    pub outer_only: SimCost,
    /// Inner (algorithm) search only.
    pub inner_only: SimCost,
    /// Both levels.
    pub both: SimCost,
}

/// Table 5: contribution of the inner search on SqueezeNet.
pub fn table5(cfg: &ExperimentConfig) -> (Table, Table5Data) {
    let g0 = models::squeezenet::build(cfg.model_cfg);
    let model = cfg.model();
    let scfg = cfg.search_config();
    let run = |outer: bool, inner: bool| -> SimCost {
        let ctx = cfg.ctx();
        let res = optimize(
            &g0,
            &ctx,
            &CostFunction::Energy,
            &SearchConfig { enable_outer: outer, enable_inner: inner, ..scfg.clone() },
        )
        .unwrap();
        measure_actual(&res.graph, &res.assignment, &model)
    };
    let origin = run(false, false);
    let outer_only = run(true, false);
    let inner_only = run(false, true);
    let both = run(true, true);

    let mut t = Table::new(
        "Table 5: contribution of inner search (SqueezeNet, energy objective)",
        &["configuration", "time_ms", "power_w", "energy_j/1k", "energy vs origin"],
    );
    for (label, c) in [
        ("origin", origin),
        ("outer_only", outer_only),
        ("inner_only", inner_only),
        ("both", both),
    ] {
        t.row(vec![
            label.to_string(),
            f3(c.time_ms),
            f3(c.power_w),
            f3(c.energy_j()),
            format!("{:+.1}%", 100.0 * (c.energy_j() / origin.energy_j() - 1.0)),
        ]);
    }
    (t, Table5Data { origin, outer_only, inner_only, both })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            seed: 7,
            // compute-bound scale but a small search budget
            model_cfg: ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 },
            search: SearchKnobs { alpha: 1.05, max_dequeues: 12 },
        }
    }

    #[test]
    fn table1_shape_holds() {
        let (_t, data) = table1(&ExperimentConfig::default());
        assert_eq!(data.nodes.len(), 3);
        // conv3 has all three algorithms; winograd (C) must win on energy
        let conv3 = &data.nodes[2].1;
        assert!(conv3.len() >= 3);
        let a = conv3.iter().find(|(al, _)| *al == Algorithm::ConvIm2col).unwrap().1;
        let b = conv3.iter().find(|(al, _)| *al == Algorithm::ConvDirect).unwrap().1;
        let c = conv3.iter().find(|(al, _)| *al == Algorithm::ConvWinograd).unwrap().1;
        assert!(c.energy_j() < a.energy_j());
        assert!(c.energy_j() < b.energy_j());
        assert!(b.power_w < a.power_w);
        // conv1/conv2: winograd not applicable
        assert!(data.nodes[0].1.iter().all(|(al, _)| *al != Algorithm::ConvWinograd));
        assert!(data.nodes[1].1.iter().all(|(al, _)| *al != Algorithm::ConvWinograd));
    }

    #[test]
    fn frontier_table_renders() {
        use crate::energysim::FreqId;
        use crate::search::PlanPoint;
        let mcfg = ModelConfig { batch: 1, resolution: 32, width_div: 8, classes: 10 };
        let g = models::simple::build_cnn(mcfg);
        let a = Assignment::default_for(&g, &crate::algo::AlgorithmRegistry::new());
        let f = PlanFrontier::from_points(vec![
            PlanPoint {
                graph: g.clone(),
                assignment: a.clone(),
                cost: GraphCost { time_ms: 1.0, energy_j: 200.0, freq: FreqId::NOMINAL },
                weight: 0.0,
                batch: 1,
            },
            PlanPoint {
                graph: g,
                assignment: a,
                cost: GraphCost { time_ms: 2.0, energy_j: 400.0, freq: FreqId::NOMINAL },
                weight: 1.0,
                batch: 8,
            },
        ]);
        let origin = GraphCost { time_ms: 3.0, energy_j: 400.0, freq: FreqId::NOMINAL };
        let r = frontier_table(&f, Some(&origin)).render();
        assert!(r.contains("latency-optimal"), "{r}");
        assert!(r.contains("energy-optimal"), "{r}");
        assert!(r.contains("origin"), "{r}");
        // The batch column renders the operating point's batch size.
        assert!(r.contains('8'), "{r}");
    }

    #[test]
    fn table5_shape_holds_tiny() {
        let (_t, d) = table5(&tiny_cfg());
        // both <= each single level <= origin (energy objective)
        assert!(d.both.energy_j() <= d.outer_only.energy_j() * 1.02);
        assert!(d.both.energy_j() <= d.inner_only.energy_j() * 1.02);
        assert!(d.inner_only.energy_j() < d.origin.energy_j());
    }
}
