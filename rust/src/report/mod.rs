//! Report formatting + the paper-table generators (Tables 1–5).

/// Paper-table generators (Tables 1-5) and the frontier table.
pub mod tables;

use std::fmt::Write as _;

/// A simple aligned text table, paper style.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table title, rendered in the `=== title ===` banner.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (each the same arity as `headers`).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given title and columns.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row. Panics on arity mismatch.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as aligned plain text.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "=== {} ===", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.len();
                let _ = write!(out, "{}{}", c, " ".repeat(pad));
                if i + 1 < ncols {
                    let _ = write!(out, "  ");
                }
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

/// Human-readable DVFS summary of a plan: "nominal", "900MHz", or a
/// mixed-state histogram like "510MHz×2 900MHz×5 nominal×9".
pub fn describe_freqs(a: &crate::algo::Assignment) -> String {
    let hist = a.freq_histogram();
    match hist.len() {
        0 => "nominal".to_string(),
        1 => hist[0].0.describe(),
        _ => hist
            .iter()
            .map(|(f, n)| format!("{}×{n}", f.describe()))
            .collect::<Vec<_>>()
            .join(" "),
    }
}

/// 3-significant-digit formatting matching the paper's tables.
pub fn f3(x: f64) -> String {
    if !x.is_finite() {
        return "-".into();
    }
    if x == 0.0 {
        return "0".into();
    }
    let digits = x.abs().log10().floor() as i32;
    let decimals = (2 - digits).max(0) as usize;
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("=== demo ==="));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn f3_formats() {
        assert_eq!(f3(0.0195), "0.0195");
        assert_eq!(f3(144.6), "145");
        assert_eq!(f3(2.81), "2.81");
        assert_eq!(f3(0.916), "0.916");
        assert_eq!(f3(f64::INFINITY), "-");
    }
}
