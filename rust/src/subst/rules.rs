//! The concrete substitution rules.
//!
//! Rules follow MetaFlow's catalogue adapted to our operator set:
//! operator fusion (conv+relu, add+relu, conv+bn, conv+residual-add),
//! parallel-convolution merging (the Inception/fire-module workhorse),
//! kernel enlargement (1×1 → padded 3×3, an *enabling* substitution that
//! costs FLOPs but unlocks merges), and split/concat cancellation.
//!
//! Each rule implements [`Rule::find_sites`] (match phase — read-only
//! scan against the shared [`MatchContext`]) and contributes a
//! [`SiteKind`] variant whose `build` method expands the matched site
//! into a [`GraphDelta`] (rewrite phase). The delta replays the exact
//! edit sequence the historical clone-and-rewrite implementations
//! performed, so materialized products are bit-identical to the old
//! engine's.

use super::{MatchContext, RewriteSite, Rule};
use crate::graph::delta::DeltaBuilder;
use crate::graph::op::{Activation, OpKind};
use crate::graph::{Graph, GraphDelta, NodeId, PortRef};

/// Shorthand for a Conv2d attribute bundle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct ConvAttrs {
    pub stride: (usize, usize),
    pub pad: (usize, usize),
    pub act: Activation,
    pub has_bias: bool,
    pub has_residual: bool,
}

pub(crate) fn conv_attrs(op: &OpKind) -> Option<ConvAttrs> {
    match op {
        OpKind::Conv2d { stride, pad, act, has_bias, has_residual } => Some(ConvAttrs {
            stride: *stride,
            pad: *pad,
            act: *act,
            has_bias: *has_bias,
            has_residual: *has_residual,
        }),
        _ => None,
    }
}

fn conv_op(a: ConvAttrs) -> OpKind {
    OpKind::Conv2d {
        stride: a.stride,
        pad: a.pad,
        act: a.act,
        has_bias: a.has_bias,
        has_residual: a.has_residual,
    }
}

/// Precomputed match data of one [`RewriteSite`], one variant per rule.
/// `build` expands it into the delta performing the rewrite.
pub(crate) enum SiteKind {
    /// `Conv2d(act=None) -> Relu` ⇒ `Conv2d(act=Relu)`.
    ConvRelu { conv: PortRef, relu: NodeId, attrs: ConvAttrs },
    /// `DwConv2d(act=None) -> Relu` ⇒ `DwConv2d(act=Relu)`.
    DwConvRelu { dw: PortRef, relu: NodeId },
    /// `Relu(Add(a, b))` ⇒ `AddRelu(a, b)`.
    AddRelu { add: PortRef, relu: NodeId },
    /// `BatchNorm(Conv2d(..))` ⇒ conv with folded parameters.
    ConvBn { bn: NodeId, conv: PortRef, attrs: ConvAttrs },
    /// `BatchNorm(DwConv2d(..))` ⇒ depthwise conv with folded parameters.
    DwConvBn { bn: NodeId, dw: PortRef },
    /// `Add(Conv2d(..), r)` ⇒ conv with fused residual input.
    ConvResidual { add: NodeId, conv: PortRef, res: PortRef, attrs: ConvAttrs, fused_relu: bool },
    /// Two parallel convs on one input ⇒ one wide conv + `Split`.
    MergeConvs { c1: NodeId, c2: NodeId, attrs: ConvAttrs, k1: usize, k2: usize },
    /// 1×1 conv ⇒ zero-padded 3×3 (enabling substitution).
    Enlarge { conv: NodeId, attrs: ConvAttrs },
    /// `Concat(Split(x).*)` in order ⇒ `x`.
    SplitConcat { cat: NodeId, x: PortRef },
    /// `Split(Concat(..))` at matching sizes ⇒ identity rewiring.
    ConcatSplit { split: NodeId },
    /// `Add(MatMul(a, b), bias)` ⇒ `MatMul(a, b, bias)` (fused epilogue).
    MatMulBias { add: NodeId, mm: PortRef, bias: PortRef },
    /// `MatMul(act=None) -> Relu` ⇒ `MatMul(act=Relu)`.
    MatMulRelu { mm: PortRef, relu: NodeId },
    /// Duplicate computation cones ⇒ every consumer reads one survivor.
    Cse { survivor: NodeId, dupes: Vec<NodeId>, ports: usize },
}

/// The shared BN-fold edit script of `ConvBn`/`DwConvBn`: fold the BN
/// parameters into weight/bias constants, emit the rewritten producer
/// (`make_op` supplies the fused conv/depthwise operator with
/// `has_bias: true`), and redirect the BN's consumers onto it. One home
/// for the sequence keeps the two rules byte-equivalent by construction.
fn build_bn_fold(
    b: &mut DeltaBuilder,
    g: &Graph,
    bn: NodeId,
    producer: NodeId,
    bias: Option<PortRef>,
    make_op: impl FnOnce() -> OpKind,
) {
    let bn_node = g.node(bn);
    let &OpKind::BatchNorm { eps } = &bn_node.op else {
        unreachable!("BN-fold site over a non-BatchNorm node")
    };
    let (gamma, beta, mean, var) =
        (bn_node.inputs[1], bn_node.inputs[2], bn_node.inputs[3], bn_node.inputs[4]);
    let p = g.node(producer);
    let w = p.inputs[1];
    let x = p.inputs[0];
    let wf = b.add(
        OpKind::FoldBnWeight { eps },
        vec![w, gamma, var],
        &format!("{}_wfold", p.name),
    );
    let mut bias_inputs = vec![gamma, beta, mean, var];
    if let Some(bp) = bias {
        bias_inputs.insert(0, bp);
    }
    let bf = b.add(
        OpKind::FoldBnBias { eps, has_bias: bias.is_some() },
        bias_inputs,
        &format!("{}_bfold", p.name),
    );
    let newp = b.add(
        make_op(),
        vec![x, PortRef::of(wf), PortRef::of(bf)],
        &format!("{}_bnfold", p.name),
    );
    b.redirect(PortRef::of(bn), PortRef::of(newp));
}

impl SiteKind {
    /// Expand the matched site into its rewrite delta. `g` must be the
    /// graph the site was found on.
    pub(crate) fn build(&self, g: &Graph) -> GraphDelta {
        let mut b = DeltaBuilder::new(g);
        match *self {
            SiteKind::ConvRelu { conv, relu, attrs } => {
                b.replace_op(conv.node, conv_op(ConvAttrs { act: Activation::Relu, ..attrs }));
                b.redirect(PortRef::of(relu), conv);
            }
            SiteKind::DwConvRelu { dw, relu } => {
                let &OpKind::DwConv2d { stride, pad, has_bias, .. } = &g.node(dw.node).op else {
                    unreachable!("DwConvRelu site over a non-depthwise node")
                };
                b.replace_op(
                    dw.node,
                    OpKind::DwConv2d { stride, pad, act: Activation::Relu, has_bias },
                );
                b.redirect(PortRef::of(relu), dw);
            }
            SiteKind::AddRelu { add, relu } => {
                b.replace_op(add.node, OpKind::AddRelu);
                b.redirect(PortRef::of(relu), add);
            }
            SiteKind::ConvBn { bn, conv, attrs } => {
                let bias = attrs.has_bias.then(|| g.node(conv.node).inputs[2]);
                build_bn_fold(&mut b, g, bn, conv.node, bias, || {
                    conv_op(ConvAttrs { has_bias: true, ..attrs })
                });
            }
            SiteKind::DwConvBn { bn, dw } => {
                let dw_node = g.node(dw.node);
                let &OpKind::DwConv2d { stride, pad, act, has_bias } = &dw_node.op else {
                    unreachable!("DwConvBn site over a non-depthwise node")
                };
                let bias = has_bias.then(|| dw_node.inputs[2]);
                build_bn_fold(&mut b, g, bn, dw.node, bias, || OpKind::DwConv2d {
                    stride,
                    pad,
                    act,
                    has_bias: true,
                });
            }
            SiteKind::ConvResidual { add, conv, res, attrs, fused_relu } => {
                let conv_node = g.node(conv.node);
                let mut inputs = conv_node.inputs.clone();
                inputs.push(res);
                let act = if fused_relu { Activation::Relu } else { Activation::None };
                let newconv = b.add(
                    conv_op(ConvAttrs { has_residual: true, act, ..attrs }),
                    inputs,
                    &format!("{}_res", conv_node.name),
                );
                b.redirect(PortRef::of(add), PortRef::of(newconv));
            }
            SiteKind::MergeConvs { c1, c2, attrs, k1, k2 } => {
                let n1 = g.node(c1);
                let n2 = g.node(c2);
                let (w1, w2) = (n1.inputs[1], n2.inputs[1]);
                let wcat = b.add(
                    OpKind::Concat { axis: 0 },
                    vec![w1, w2],
                    &format!("{}+{}_w", n1.name, n2.name),
                );
                let mut inputs = vec![n1.inputs[0], PortRef::of(wcat)];
                if attrs.has_bias {
                    let bcat = b.add(
                        OpKind::Concat { axis: 0 },
                        vec![n1.inputs[2], n2.inputs[2]],
                        &format!("{}+{}_b", n1.name, n2.name),
                    );
                    inputs.push(PortRef::of(bcat));
                }
                let merged = b.add(conv_op(attrs), inputs, &format!("{}+{}", n1.name, n2.name));
                let split = b.add(
                    OpKind::Split { axis: 1, sizes: vec![k1, k2] },
                    vec![PortRef::of(merged)],
                    &format!("{}+{}_split", n1.name, n2.name),
                );
                b.redirect(PortRef::of(c1), PortRef { node: split, port: 0 });
                b.redirect(PortRef::of(c2), PortRef { node: split, port: 1 });
            }
            SiteKind::Enlarge { conv, attrs } => {
                let node = g.node(conv);
                let w = node.inputs[1];
                let padded = b.add(
                    OpKind::PadKernel { target: (3, 3) },
                    vec![w],
                    &format!("{}_wpad", node.name),
                );
                let mut inputs = node.inputs.clone();
                inputs[1] = PortRef::of(padded);
                let enlarged = b.add(
                    conv_op(ConvAttrs { pad: (1, 1), ..attrs }),
                    inputs,
                    &format!("{}_3x3", node.name),
                );
                b.redirect(PortRef::of(conv), PortRef::of(enlarged));
            }
            SiteKind::SplitConcat { cat, x } => {
                b.redirect(PortRef::of(cat), x);
            }
            SiteKind::ConcatSplit { split } => {
                let cat = g.node(g.node(split).inputs[0].node);
                for (port, src) in cat.inputs.iter().enumerate() {
                    b.redirect(PortRef { node: split, port }, *src);
                }
            }
            SiteKind::MatMulBias { add, mm, bias } => {
                let mm_node = g.node(mm.node);
                let OpKind::MatMul { act, .. } = mm_node.op else {
                    unreachable!("MatMulBias site over a non-matmul node")
                };
                let mut inputs = mm_node.inputs.clone();
                inputs.push(bias);
                let fused = b.add(
                    OpKind::MatMul { act, has_bias: true },
                    inputs,
                    &format!("{}_bias", mm_node.name),
                );
                b.redirect(PortRef::of(add), PortRef::of(fused));
            }
            SiteKind::MatMulRelu { mm, relu } => {
                let OpKind::MatMul { has_bias, .. } = g.node(mm.node).op else {
                    unreachable!("MatMulRelu site over a non-matmul node")
                };
                b.replace_op(mm.node, OpKind::MatMul { act: Activation::Relu, has_bias });
                b.redirect(PortRef::of(relu), mm);
            }
            SiteKind::Cse { survivor, ref dupes, ports } => {
                for &d in dupes {
                    for port in 0..ports {
                        b.redirect(PortRef { node: d, port }, PortRef { node: survivor, port });
                    }
                }
            }
        }
        b.finish()
    }
}

// ---------------------------------------------------------------------------
// Rule: Conv2d(act=None) followed by Relu  =>  Conv2d(act=Relu)
// ---------------------------------------------------------------------------
/// Fuse `Conv2d(act=None) -> Relu` into `Conv2d(act=Relu)`.
pub struct FuseConvRelu;

impl Rule for FuseConvRelu {
    fn name(&self) -> &'static str {
        "fuse_conv_relu"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let mut out = Vec::new();
        for (relu_id, relu) in g.nodes() {
            if relu.op != OpKind::Relu {
                continue;
            }
            let conv_port = relu.inputs[0];
            let conv = g.node(conv_port.node);
            let Some(attrs) = conv_attrs(&conv.op) else { continue };
            if attrs.act != Activation::None {
                continue;
            }
            // The conv's output must feed only this relu, otherwise other
            // consumers would observe pre-activation values.
            if cx.fanout(conv_port) != 1 {
                continue;
            }
            out.push(RewriteSite {
                rule: self.name(),
                anchor: relu_id,
                kind: SiteKind::ConvRelu { conv: conv_port, relu: relu_id, attrs },
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: DwConv2d(act=None) followed by Relu => DwConv2d(act=Relu)
// ---------------------------------------------------------------------------
/// Fuse `DwConv2d(act=None) -> Relu` into `DwConv2d(act=Relu)`.
pub struct FuseDwConvRelu;

impl Rule for FuseDwConvRelu {
    fn name(&self) -> &'static str {
        "fuse_dwconv_relu"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let mut out = Vec::new();
        for (relu_id, relu) in g.nodes() {
            if relu.op != OpKind::Relu {
                continue;
            }
            let dw_port = relu.inputs[0];
            let dw = g.node(dw_port.node);
            let OpKind::DwConv2d { act, .. } = dw.op else { continue };
            if act != Activation::None || cx.fanout(dw_port) != 1 {
                continue;
            }
            out.push(RewriteSite {
                rule: self.name(),
                anchor: relu_id,
                kind: SiteKind::DwConvRelu { dw: dw_port, relu: relu_id },
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: BatchNorm(DwConv2d(x, w[, b])) => DwConv2d with folded params.
// Depthwise output channel k is produced by filter w[k,0,:,:], so the same
// FoldBnWeight (per-out-channel scale) applies.
// ---------------------------------------------------------------------------
/// Fold a BatchNorm following a depthwise conv into its weights.
pub struct FuseDwConvBn;

impl Rule for FuseDwConvBn {
    fn name(&self) -> &'static str {
        "fuse_dwconv_bn"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let mut out = Vec::new();
        for (bn_id, bn) in g.nodes() {
            let OpKind::BatchNorm { .. } = bn.op else { continue };
            let dw_port = bn.inputs[0];
            let dw = g.node(dw_port.node);
            let OpKind::DwConv2d { act, .. } = dw.op else { continue };
            if act != Activation::None || cx.fanout(dw_port) != 1 {
                continue;
            }
            out.push(RewriteSite {
                rule: self.name(),
                anchor: bn_id,
                kind: SiteKind::DwConvBn { bn: bn_id, dw: dw_port },
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: Relu(Add(a, b)) => AddRelu(a, b)
// ---------------------------------------------------------------------------
/// Fuse `Add -> Relu` into the fused `AddRelu` operator.
pub struct FuseAddRelu;

impl Rule for FuseAddRelu {
    fn name(&self) -> &'static str {
        "fuse_add_relu"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let mut out = Vec::new();
        for (relu_id, relu) in g.nodes() {
            if relu.op != OpKind::Relu {
                continue;
            }
            let add_port = relu.inputs[0];
            let add = g.node(add_port.node);
            if add.op != OpKind::Add || cx.fanout(add_port) != 1 {
                continue;
            }
            out.push(RewriteSite {
                rule: self.name(),
                anchor: relu_id,
                kind: SiteKind::AddRelu { add: add_port, relu: relu_id },
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: BatchNorm(Conv2d(x, w[, b])) => Conv2d(x, w', b') with folded params
// w'[k] = w[k] * gamma[k]/sqrt(var[k]+eps);  b' = (b - mean)*scale + beta
// ---------------------------------------------------------------------------
/// Fold a BatchNorm following a conv into its weights and bias.
pub struct FuseConvBn;

impl Rule for FuseConvBn {
    fn name(&self) -> &'static str {
        "fuse_conv_bn"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let mut out = Vec::new();
        for (bn_id, bn) in g.nodes() {
            let OpKind::BatchNorm { .. } = bn.op else { continue };
            let conv_port = bn.inputs[0];
            let conv = g.node(conv_port.node);
            let Some(attrs) = conv_attrs(&conv.op) else { continue };
            // Fold is only valid when nothing intervenes: pre-activation,
            // un-shared output, no fused residual (residual is added before
            // BN would see it, changing semantics).
            if attrs.act != Activation::None
                || attrs.has_residual
                || cx.fanout(conv_port) != 1
            {
                continue;
            }
            out.push(RewriteSite {
                rule: self.name(),
                anchor: bn_id,
                kind: SiteKind::ConvBn { bn: bn_id, conv: conv_port, attrs },
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: Add(Conv2d(x, w[, b]), r) => Conv2d(x, w[, b], residual=r)
// (and symmetrically Add(r, Conv..)). cuDNN-style epilogue residual fusion.
// ---------------------------------------------------------------------------
/// Fuse a residual `Add` into the producing conv (ResNet idiom).
pub struct FuseConvResidual;

impl Rule for FuseConvResidual {
    fn name(&self) -> &'static str {
        "fuse_conv_residual"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let mut out = Vec::new();
        for (add_id, add) in g.nodes() {
            let fused_relu = match add.op {
                OpKind::Add => false,
                OpKind::AddRelu => true,
                _ => continue,
            };
            for (conv_slot, res_slot) in [(0usize, 1usize), (1, 0)] {
                let conv_port = add.inputs[conv_slot];
                let res_port = add.inputs[res_slot];
                let conv = g.node(conv_port.node);
                let Some(attrs) = conv_attrs(&conv.op) else { continue };
                if attrs.has_residual
                    || attrs.act != Activation::None
                    || cx.fanout(conv_port) != 1
                {
                    continue;
                }
                // The residual must not itself be the conv (degenerate).
                if res_port == conv_port {
                    continue;
                }
                out.push(RewriteSite {
                    rule: self.name(),
                    anchor: add_id,
                    kind: SiteKind::ConvResidual {
                        add: add_id,
                        conv: conv_port,
                        res: res_port,
                        attrs,
                        fused_relu,
                    },
                });
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: two parallel Conv2d on the same input with identical attrs and
// kernel size => one Conv2d with concatenated filters + Split.
// The Inception-branch / fire-module merge from MetaFlow.
// ---------------------------------------------------------------------------
/// Merge parallel same-shape convs sharing an input into one wider conv.
pub struct MergeParallelConvs;

impl Rule for MergeParallelConvs {
    fn name(&self) -> &'static str {
        "merge_parallel_convs"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let shapes = cx.shapes();
        let convs: Vec<(NodeId, ConvAttrs)> = g
            .nodes()
            .filter_map(|(id, n)| conv_attrs(&n.op).map(|a| (id, a)))
            .collect();
        let mut out = Vec::new();
        for i in 0..convs.len() {
            for j in (i + 1)..convs.len() {
                let (c1, a1) = convs[i];
                let (c2, a2) = convs[j];
                if a1 != a2 || a1.has_residual {
                    continue;
                }
                let n1 = g.node(c1);
                let n2 = g.node(c2);
                if n1.inputs[0] != n2.inputs[0] {
                    continue; // different input tensor
                }
                let w1 = n1.inputs[1];
                let w2 = n2.inputs[1];
                let ws1 = &shapes[w1.node.0][w1.port];
                let ws2 = &shapes[w2.node.0][w2.port];
                if ws1[2] != ws2[2] || ws1[3] != ws2[3] {
                    continue; // kernel size mismatch (EnlargeConvKernel can fix)
                }
                let (k1, k2) = (ws1[0], ws2[0]);
                out.push(RewriteSite {
                    rule: self.name(),
                    anchor: c1,
                    kind: SiteKind::MergeConvs { c1, c2, attrs: a1, k1, k2 },
                });
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: 1x1 stride-1 pad-0 Conv2d => 3x3 pad-1 Conv2d with zero-padded
// kernel. Pure enabler: costs FLOPs, unlocks MergeParallelConvs with 3x3
// siblings (MetaFlow's kernel enlargement).
// ---------------------------------------------------------------------------
/// Enlarge a 1x1 conv to a zero-padded 3x3 (enabling substitution).
pub struct EnlargeConvKernel;

impl Rule for EnlargeConvKernel {
    fn name(&self) -> &'static str {
        "enlarge_conv_kernel"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let shapes = cx.shapes();
        let mut out = Vec::new();
        for (id, node) in g.nodes() {
            let Some(attrs) = conv_attrs(&node.op) else { continue };
            if attrs.stride != (1, 1) || attrs.pad != (0, 0) {
                continue;
            }
            let w = node.inputs[1];
            let ws = &shapes[w.node.0][w.port];
            if (ws[2], ws[3]) != (1, 1) {
                continue;
            }
            // Only worth proposing when a 3x3 sibling shares our input —
            // otherwise the product graph is strictly worse and just bloats
            // the queue. (The outer search would still reject it; this is a
            // search-space hygiene heuristic, same spirit as MetaFlow's.)
            let x = node.inputs[0];
            let has_3x3_sibling = g.nodes().any(|(sid, sn)| {
                sid != id
                    && conv_attrs(&sn.op).is_some_and(|sa| {
                        sa.stride == (1, 1)
                            && sn.inputs[0] == x
                            && {
                                let sw = sn.inputs[1];
                                let sws = &shapes[sw.node.0][sw.port];
                                (sws[2], sws[3]) == (3, 3)
                            }
                    })
            });
            if !has_3x3_sibling {
                continue;
            }
            out.push(RewriteSite {
                rule: self.name(),
                anchor: id,
                kind: SiteKind::Enlarge { conv: id, attrs },
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: Concat(Split(x).0, Split(x).1, ...) over all ports in order => x
// ---------------------------------------------------------------------------
/// Cancel a `Split` whose parts are immediately re-`Concat`ed.
pub struct SplitConcatElim;

impl Rule for SplitConcatElim {
    fn name(&self) -> &'static str {
        "split_concat_elim"
    }

    fn find_sites(&self, g: &Graph, _cx: &MatchContext) -> Vec<RewriteSite> {
        let mut out = Vec::new();
        for (cat_id, cat) in g.nodes() {
            let OpKind::Concat { axis } = cat.op else { continue };
            if cat.inputs.is_empty() {
                continue;
            }
            let split_id = cat.inputs[0].node;
            let OpKind::Split { axis: s_axis, sizes } = &g.node(split_id).op else { continue };
            if *s_axis != axis || cat.inputs.len() != sizes.len() {
                continue;
            }
            let all_ports_in_order = cat
                .inputs
                .iter()
                .enumerate()
                .all(|(i, p)| p.node == split_id && p.port == i);
            if !all_ports_in_order {
                continue;
            }
            let x = g.node(split_id).inputs[0];
            out.push(RewriteSite {
                rule: self.name(),
                anchor: cat_id,
                kind: SiteKind::SplitConcat { cat: cat_id, x },
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: Split(Concat(a, b, ...)) with matching sizes => identity rewiring
// ---------------------------------------------------------------------------
/// Cancel a `Concat` immediately re-`Split` at the same sizes.
pub struct ConcatSplitElim;

impl Rule for ConcatSplitElim {
    fn name(&self) -> &'static str {
        "concat_split_elim"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let shapes = cx.shapes();
        let mut out = Vec::new();
        for (split_id, split) in g.nodes() {
            let OpKind::Split { axis, sizes } = &split.op else { continue };
            let cat_port = split.inputs[0];
            let cat = g.node(cat_port.node);
            let OpKind::Concat { axis: c_axis } = cat.op else { continue };
            if c_axis != *axis || cat.inputs.len() != sizes.len() {
                continue;
            }
            let part_sizes: Vec<usize> = cat
                .inputs
                .iter()
                .map(|p| shapes[p.node.0][p.port][*axis])
                .collect();
            if &part_sizes != sizes {
                continue;
            }
            out.push(RewriteSite {
                rule: self.name(),
                anchor: split_id,
                kind: SiteKind::ConcatSplit { split: split_id },
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: MatMul epilogue fusion — Add(MatMul(a,b), bias) => MatMul(a,b,bias)
// and MatMul(act=None) -> Relu => MatMul(act=Relu). The matmul-side analogue
// of the conv epilogue family (attention/FFN blocks, classifier heads).
// ---------------------------------------------------------------------------
/// Fuse a constant bias `Add` and/or a following `Relu` into a `MatMul`.
pub struct FuseMatMulBiasAct;

impl Rule for FuseMatMulBiasAct {
    fn name(&self) -> &'static str {
        "fuse_matmul_epilogue"
    }

    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let shapes = cx.shapes();
        let mut out = Vec::new();
        for (id, node) in g.nodes() {
            match node.op {
                OpKind::Relu => {
                    let mm_port = node.inputs[0];
                    let OpKind::MatMul { act, .. } = g.node(mm_port.node).op else { continue };
                    if act != Activation::None || cx.fanout(mm_port) != 1 {
                        continue;
                    }
                    out.push(RewriteSite {
                        rule: self.name(),
                        anchor: id,
                        kind: SiteKind::MatMulRelu { mm: mm_port, relu: id },
                    });
                }
                OpKind::Add => {
                    for (mm_slot, bias_slot) in [(0usize, 1usize), (1, 0)] {
                        let mm_port = node.inputs[mm_slot];
                        let bias_port = node.inputs[bias_slot];
                        let OpKind::MatMul { act, has_bias } = g.node(mm_port.node).op else {
                            continue;
                        };
                        // The matmul must still have a free bias slot and no
                        // epilogue (activation runs after the bias add), and
                        // its output must feed only this Add.
                        if act != Activation::None || has_bias || cx.fanout(mm_port) != 1 {
                            continue;
                        }
                        // Only a constant-space operand is a bias (a runtime
                        // operand is a genuine elementwise add), and the
                        // MatMul bias input contract is the full output shape.
                        if !g.node(bias_port.node).op.is_constant_space() {
                            continue;
                        }
                        if shapes[bias_port.node.0][bias_port.port]
                            != shapes[mm_port.node.0][mm_port.port]
                        {
                            continue;
                        }
                        out.push(RewriteSite {
                            rule: self.name(),
                            anchor: id,
                            kind: SiteKind::MatMulBias { add: id, mm: mm_port, bias: bias_port },
                        });
                    }
                }
                _ => {}
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Rule: common-subexpression elimination over the Merkle node hashes.
// Two runtime nodes with equal hashes compute identical values on identical
// inputs (the same invariant the outer search's dedup rests on), so every
// consumer of a duplicate can read the lowest-numbered survivor instead;
// the duplicate cones die by liveness. One site per duplicate group.
// ---------------------------------------------------------------------------
/// Redirect duplicate computations (equal Merkle hashes) through one node.
pub struct Cse;

impl Rule for Cse {
    fn name(&self) -> &'static str {
        "cse"
    }

    fn find_sites(&self, g: &Graph, _cx: &MatchContext) -> Vec<RewriteSite> {
        let Some(hashes) = crate::graph::canonical::node_hashes(g) else {
            return Vec::new();
        };
        let mut groups: std::collections::BTreeMap<u64, Vec<NodeId>> = Default::default();
        for (id, node) in g.nodes() {
            // Constant-space nodes are folded away before the request path
            // (nothing to save), and Input nodes hash by shape alone — two
            // same-shape graph inputs are distinct tensors, not duplicates.
            if node.op.is_constant_space() || matches!(node.op, OpKind::Input { .. }) {
                continue;
            }
            groups.entry(hashes[id.0]).or_default().push(id);
        }
        let mut sites: Vec<RewriteSite> = groups
            .into_values()
            .filter(|members| members.len() > 1)
            .map(|members| {
                let survivor = members[0]; // g.nodes() yields ascending ids
                let ports = g.node(survivor).op.num_outputs();
                RewriteSite {
                    rule: self.name(),
                    anchor: survivor,
                    kind: SiteKind::Cse { survivor, dupes: members[1..].to_vec(), ports },
                }
            })
            .collect();
        sites.sort_by_key(|s| s.anchor);
        sites
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::eps_bits;
    use crate::subst::RuleSet;

    fn conv2d(act: Activation, has_bias: bool) -> OpKind {
        OpKind::Conv2d { stride: (1, 1), pad: (1, 1), act, has_bias, has_residual: false }
    }

    fn input(g: &mut Graph, shape: &[usize]) -> NodeId {
        g.add1(OpKind::Input { shape: shape.to_vec() }, &[], "x")
    }

    fn weight(g: &mut Graph, shape: &[usize], seed: u64) -> NodeId {
        g.add1(OpKind::weight(shape.to_vec(), seed), &[], "w")
    }

    #[test]
    fn fuse_conv_relu_fires_once() {
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 3, 8, 8]);
        let w = weight(&mut g, &[4, 3, 3, 3], 1);
        let c = g.add1(conv2d(Activation::None, false), &[x, w], "c");
        let r = g.add1(OpKind::Relu, &[c], "r");
        g.outputs = vec![PortRef::of(r)];

        let sites = FuseConvRelu.find_sites(&g, &MatchContext::new(&g).unwrap());
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0].anchor(), r);
        assert_eq!(sites[0].rule_name(), "fuse_conv_relu");
        let products = FuseConvRelu.apply_all(&g).unwrap();
        assert_eq!(products.len(), 1);
        let mut ng = products.into_iter().next().unwrap();
        ng.compact();
        ng.validate().unwrap();
        assert_eq!(ng.runtime_node_count(), 2); // input + fused conv
        let fused = ng
            .nodes()
            .find_map(|(_, n)| conv_attrs(&n.op))
            .unwrap();
        assert_eq!(fused.act, Activation::Relu);
    }

    #[test]
    fn fuse_conv_relu_blocked_by_fanout() {
        // conv output also consumed by a second relu: must not fuse.
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 3, 8, 8]);
        let w = weight(&mut g, &[4, 3, 3, 3], 1);
        let c = g.add1(conv2d(Activation::None, false), &[x, w], "c");
        let r1 = g.add1(OpKind::Relu, &[c], "r1");
        let r2 = g.add1(OpKind::Sigmoid, &[c], "r2");
        g.outputs = vec![PortRef::of(r1), PortRef::of(r2)];
        assert!(FuseConvRelu.apply_all(&g).unwrap().is_empty());
    }

    #[test]
    fn fuse_conv_bn_folds_params() {
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 3, 8, 8]);
        let w = weight(&mut g, &[4, 3, 3, 3], 1);
        let c = g.add1(conv2d(Activation::None, false), &[x, w], "c");
        let gamma = weight(&mut g, &[4], 2);
        let beta = weight(&mut g, &[4], 3);
        let mean = weight(&mut g, &[4], 4);
        let var = weight(&mut g, &[4], 5);
        let bn = g.add1(
            OpKind::BatchNorm { eps: eps_bits(1e-5) },
            &[c, gamma, beta, mean, var],
            "bn",
        );
        g.outputs = vec![PortRef::of(bn)];

        let products = FuseConvBn.apply_all(&g).unwrap();
        assert_eq!(products.len(), 1);
        let mut ng = products.into_iter().next().unwrap();
        ng.compact();
        ng.validate().unwrap();
        // BatchNorm gone; FoldBn ops present; conv now has bias.
        assert!(ng.nodes().all(|(_, n)| !matches!(n.op, OpKind::BatchNorm { .. })));
        assert!(ng.nodes().any(|(_, n)| matches!(n.op, OpKind::FoldBnWeight { .. })));
        let fused = ng.nodes().find_map(|(_, n)| conv_attrs(&n.op)).unwrap();
        assert!(fused.has_bias);
    }

    #[test]
    fn merge_parallel_convs_creates_split() {
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 3, 8, 8]);
        let w1 = weight(&mut g, &[4, 3, 3, 3], 1);
        let w2 = weight(&mut g, &[6, 3, 3, 3], 2);
        let c1 = g.add1(conv2d(Activation::Relu, false), &[x, w1], "c1");
        let c2 = g.add1(conv2d(Activation::Relu, false), &[x, w2], "c2");
        let cat = g.add1(OpKind::Concat { axis: 1 }, &[c1, c2], "cat");
        g.outputs = vec![PortRef::of(cat)];

        let products = MergeParallelConvs.apply_all(&g).unwrap();
        assert_eq!(products.len(), 1);
        let mut ng = products.into_iter().next().unwrap();
        ng.compact();
        ng.validate().unwrap();
        // one merged conv remains
        let convs: Vec<_> = ng.nodes().filter(|(_, n)| conv_attrs(&n.op).is_some()).collect();
        assert_eq!(convs.len(), 1);
        assert!(ng.nodes().any(|(_, n)| matches!(n.op, OpKind::Split { .. })));
        let shapes = ng.infer_shapes().unwrap();
        // merged conv outputs 10 channels
        let (cid, _) = convs[0];
        assert_eq!(shapes[cid.0][0][1], 10);
    }

    #[test]
    fn merge_requires_same_attrs() {
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 3, 8, 8]);
        let w1 = weight(&mut g, &[4, 3, 3, 3], 1);
        let w2 = weight(&mut g, &[6, 3, 3, 3], 2);
        let c1 = g.add1(conv2d(Activation::Relu, false), &[x, w1], "c1");
        let c2 = g.add1(conv2d(Activation::None, false), &[x, w2], "c2"); // act differs
        g.outputs = vec![PortRef::of(c1), PortRef::of(c2)];
        assert!(MergeParallelConvs.apply_all(&g).unwrap().is_empty());
    }

    #[test]
    fn enlarge_fires_only_with_3x3_sibling() {
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 3, 8, 8]);
        let w1 = weight(&mut g, &[4, 3, 1, 1], 1);
        let c1 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (0, 0),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
            &[x, w1],
            "c1x1",
        );
        g.outputs = vec![PortRef::of(c1)];
        // alone: no product
        assert!(EnlargeConvKernel.apply_all(&g).unwrap().is_empty());
        // add a 3x3 sibling
        let w2 = weight(&mut g, &[6, 3, 3, 3], 2);
        let c2 = g.add1(conv2d(Activation::Relu, false), &[x, w2], "c3x3");
        g.outputs = vec![PortRef::of(c1), PortRef::of(c2)];
        let products = EnlargeConvKernel.apply_all(&g).unwrap();
        assert_eq!(products.len(), 1);
        let mut ng = products.into_iter().next().unwrap();
        ng.compact();
        ng.validate().unwrap();
        assert!(ng.nodes().any(|(_, n)| matches!(n.op, OpKind::PadKernel { .. })));
        // enlarged conv output shape unchanged (8x8 spatial)
        let shapes = ng.infer_shapes().unwrap();
        for out in &ng.outputs {
            assert_eq!(shapes[out.node.0][out.port][2], 8);
        }
    }

    #[test]
    fn split_concat_elim() {
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 8, 4, 4]);
        let s = g.add1(OpKind::Split { axis: 1, sizes: vec![3, 5] }, &[x], "s");
        let cat = g.add(
            OpKind::Concat { axis: 1 },
            vec![PortRef { node: s, port: 0 }, PortRef { node: s, port: 1 }],
            "cat",
        );
        let r = g.add1(OpKind::Relu, &[cat], "r");
        g.outputs = vec![PortRef::of(r)];
        let products = SplitConcatElim.apply_all(&g).unwrap();
        assert_eq!(products.len(), 1);
        let mut ng = products.into_iter().next().unwrap();
        ng.compact();
        ng.validate().unwrap();
        assert_eq!(ng.len(), 2); // input + relu
    }

    #[test]
    fn split_concat_elim_requires_order() {
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 8, 4, 4]);
        let s = g.add1(OpKind::Split { axis: 1, sizes: vec![4, 4] }, &[x], "s");
        // swapped order: NOT equivalent to x (channels permuted)
        let cat = g.add(
            OpKind::Concat { axis: 1 },
            vec![PortRef { node: s, port: 1 }, PortRef { node: s, port: 0 }],
            "cat",
        );
        g.outputs = vec![PortRef::of(cat)];
        assert!(SplitConcatElim.apply_all(&g).unwrap().is_empty());
    }

    #[test]
    fn concat_split_elim_rewires_ports() {
        let mut g = Graph::new();
        let a = input(&mut g, &[1, 3, 4, 4]);
        let b = g.add1(OpKind::Input { shape: vec![1, 5, 4, 4] }, &[], "b");
        let cat = g.add1(OpKind::Concat { axis: 1 }, &[a, b], "cat");
        let s = g.add1(OpKind::Split { axis: 1, sizes: vec![3, 5] }, &[cat], "s");
        let r0 = g.add(OpKind::Relu, vec![PortRef { node: s, port: 0 }], "r0");
        let r1 = g.add(OpKind::Relu, vec![PortRef { node: s, port: 1 }], "r1");
        g.outputs = vec![PortRef::of(r0), PortRef::of(r1)];
        let products = ConcatSplitElim.apply_all(&g).unwrap();
        assert_eq!(products.len(), 1);
        let mut ng = products.into_iter().next().unwrap();
        ng.compact();
        ng.validate().unwrap();
        // concat+split both dead now
        assert_eq!(ng.len(), 4);
    }

    #[test]
    fn fuse_conv_residual() {
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 4, 8, 8]);
        let w = weight(&mut g, &[4, 4, 3, 3], 1);
        let c = g.add1(conv2d(Activation::None, false), &[x, w], "c");
        let add = g.add1(OpKind::Add, &[c, x], "add");
        let r = g.add1(OpKind::Relu, &[add], "r");
        g.outputs = vec![PortRef::of(r)];
        let products = FuseConvResidual.apply_all(&g).unwrap();
        assert_eq!(products.len(), 1);
        let mut ng = products.into_iter().next().unwrap();
        ng.compact();
        ng.validate().unwrap();
        let fused = ng.nodes().find_map(|(_, n)| conv_attrs(&n.op)).unwrap();
        assert!(fused.has_residual);
    }

    #[test]
    fn fuse_matmul_bias_then_relu() {
        // x @ w + bias, then relu: two rounds fold the whole epilogue in.
        let mut g = Graph::new();
        let x = input(&mut g, &[4, 16]);
        let w = weight(&mut g, &[16, 8], 1);
        let m = g.add1(OpKind::matmul(), &[x, w], "m");
        let bias = weight(&mut g, &[4, 8], 2);
        let add = g.add1(OpKind::Add, &[m, bias], "add");
        let r = g.add1(OpKind::Relu, &[add], "r");
        g.outputs = vec![PortRef::of(r)];
        g.validate().unwrap();

        let sites = FuseMatMulBiasAct.find_sites(&g, &MatchContext::new(&g).unwrap());
        assert_eq!(sites.len(), 1, "only the bias add matches before it folds");
        let mut g1 = FuseMatMulBiasAct.apply_all(&g).unwrap().into_iter().next().unwrap();
        g1.compact();
        g1.validate().unwrap();
        let OpKind::MatMul { act, has_bias } =
            g1.nodes().find_map(|(_, n)| matches!(n.op, OpKind::MatMul { .. }).then(|| n.op.clone())).unwrap()
        else {
            unreachable!()
        };
        assert!(has_bias);
        assert_eq!(act, Activation::None);

        let mut g2 = FuseMatMulBiasAct.apply_all(&g1).unwrap().into_iter().next().unwrap();
        g2.compact();
        g2.validate().unwrap();
        assert_eq!(g2.runtime_node_count(), 2); // input + fully fused matmul
        let OpKind::MatMul { act, has_bias } =
            g2.nodes().find_map(|(_, n)| matches!(n.op, OpKind::MatMul { .. }).then(|| n.op.clone())).unwrap()
        else {
            unreachable!()
        };
        assert!(has_bias);
        assert_eq!(act, Activation::Relu);
    }

    #[test]
    fn fuse_matmul_bias_guards() {
        // A runtime (non-constant) operand is a real elementwise add, and a
        // shared matmul output must not fuse either.
        let mut g = Graph::new();
        let x = input(&mut g, &[4, 16]);
        let w = weight(&mut g, &[16, 8], 1);
        let m = g.add1(OpKind::matmul(), &[x, w], "m");
        let y = g.add1(OpKind::Input { shape: vec![4, 8] }, &[], "y");
        let add = g.add1(OpKind::Add, &[m, y], "add");
        g.outputs = vec![PortRef::of(add)];
        assert!(FuseMatMulBiasAct.apply_all(&g).unwrap().is_empty());

        let mut g = Graph::new();
        let x = input(&mut g, &[4, 16]);
        let w = weight(&mut g, &[16, 8], 1);
        let m = g.add1(OpKind::matmul(), &[x, w], "m");
        let bias = weight(&mut g, &[4, 8], 2);
        let add = g.add1(OpKind::Add, &[m, bias], "add");
        let s = g.add1(OpKind::Sigmoid, &[m], "s"); // second consumer
        g.outputs = vec![PortRef::of(add), PortRef::of(s)];
        assert!(FuseMatMulBiasAct.apply_all(&g).unwrap().is_empty());
    }

    #[test]
    fn cse_merges_duplicate_cones_and_preserves_hash() {
        use crate::graph::canonical::graph_hash;
        // Two matmuls over tied weights (same seed, same shape) are the
        // same computation: consumers should read one survivor.
        let mut g = Graph::new();
        let x = input(&mut g, &[4, 16]);
        let w1 = weight(&mut g, &[16, 8], 7);
        let w2 = weight(&mut g, &[16, 8], 7); // tied: identical constant
        let m1 = g.add1(OpKind::matmul(), &[x, w1], "m1");
        let m2 = g.add1(OpKind::matmul(), &[x, w2], "m2");
        let add = g.add1(OpKind::Add, &[m1, m2], "add");
        g.outputs = vec![PortRef::of(add)];
        g.validate().unwrap();

        let sites = Cse.find_sites(&g, &MatchContext::new(&g).unwrap());
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0].anchor(), m1);
        let before = graph_hash(&g);
        let mut ng = Cse.apply_all(&g).unwrap().into_iter().next().unwrap();
        ng.compact();
        ng.validate().unwrap();
        // Duplicate cone (m2, w2) is dead; the add reads m1 twice.
        assert_eq!(ng.runtime_node_count(), 3); // input + matmul + add
        assert_eq!(graph_hash(&ng), before, "CSE must preserve the Merkle output hash");
    }

    #[test]
    fn cse_skips_inputs_and_distinct_weights() {
        // Same-shape graph inputs are distinct tensors; distinct seeds are
        // distinct constants — neither may merge.
        let mut g = Graph::new();
        let a = input(&mut g, &[4, 16]);
        let b2 = g.add1(OpKind::Input { shape: vec![4, 16] }, &[], "b");
        let w1 = weight(&mut g, &[16, 8], 1);
        let w2 = weight(&mut g, &[16, 8], 2);
        let m1 = g.add1(OpKind::matmul(), &[a, w1], "m1");
        let m2 = g.add1(OpKind::matmul(), &[b2, w2], "m2");
        let add = g.add1(OpKind::Add, &[m1, m2], "add");
        g.outputs = vec![PortRef::of(add)];
        assert!(Cse.apply_all(&g).unwrap().is_empty());
    }

    #[test]
    fn ruleset_neighbors_on_fire_like_block() {
        // squeeze 1x1 -> two expand convs (1x1 and 3x3) -> concat: the
        // SqueezeNet fire module. Several rules should fire.
        let mut g = Graph::new();
        let x = input(&mut g, &[1, 8, 8, 8]);
        let ws = weight(&mut g, &[4, 8, 1, 1], 1);
        let bs = weight(&mut g, &[4], 10);
        let sq = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (0, 0),
                act: Activation::Relu,
                has_bias: true,
                has_residual: false,
            },
            &[x, ws, bs],
            "squeeze",
        );
        let we1 = weight(&mut g, &[8, 4, 1, 1], 2);
        let be1 = weight(&mut g, &[8], 11);
        let e1 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (0, 0),
                act: Activation::Relu,
                has_bias: true,
                has_residual: false,
            },
            &[sq, we1, be1],
            "exp1x1",
        );
        let we3 = weight(&mut g, &[8, 4, 3, 3], 3);
        let be3 = weight(&mut g, &[8], 12);
        let e3 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: true,
                has_residual: false,
            },
            &[sq, we3, be3],
            "exp3x3",
        );
        let cat = g.add1(OpKind::Concat { axis: 1 }, &[e1, e3], "cat");
        g.outputs = vec![PortRef::of(cat)];
        g.validate().unwrap();

        let rs = RuleSet::standard();
        let neighbors = rs.neighbors(&g).unwrap();
        // at least the enlarge rule fires (1x1 expand with a 3x3 sibling)
        assert!(
            neighbors.iter().any(|(_, name)| *name == "enlarge_conv_kernel"),
            "neighbors: {:?}",
            neighbors.iter().map(|(_, n)| *n).collect::<Vec<_>>()
        );
        // all neighbors validate
        for (ng, _) in &neighbors {
            ng.validate().unwrap();
        }
    }
}
