//! Equivalent graph substitutions (paper §3.1) — the **two-phase delta
//! engine**.
//!
//! A substitution `S` takes a graph, transforms a matched subgraph by a
//! rule, and produces one or more new graphs that are *equivalent*: for any
//! input tensors they produce the same output tensors. The closure of a
//! graph under a rule set is the paper's "equivalent graph space" that the
//! outer search explores.
//!
//! Rules run in two phases:
//!
//! 1. **Match** — [`Rule::find_sites`] scans the graph once (against a
//!    shared [`MatchContext`] carrying precomputed shapes and a fanout
//!    map) and returns every [`RewriteSite`]: a matched anchor plus the
//!    rule data needed to rewrite it.
//! 2. **Expand** — [`RewriteSite::delta`] turns a site into a
//!    [`GraphDelta`] (nodes replaced/added, ports rewired). The search
//!    evaluates the delta incrementally (cost carry-over, incremental
//!    hash) and only materializes a full graph — via
//!    [`Graph::apply_delta`] — for wave winners.
//!
//! Every rule here is verified for semantic equivalence two ways: unit
//! tests on structure, and randomized end-to-end executions of
//! (original, substituted) pairs through the reference engine (see
//! `rust/tests/prop_invariants.rs`); the delta artifacts are additionally
//! property-checked against full rebuilds in `rust/tests/delta_engine.rs`.

/// The concrete substitution rules (fusions, merges, eliminations).
pub mod rules;

use crate::graph::{Graph, GraphDelta, NodeId, PortRef, TensorShape};
use std::borrow::Cow;
use std::collections::BTreeMap;

/// Precomputed per-graph match context shared by every rule: the full
/// shape table and a port-fanout map, each computed **once per graph**
/// instead of once per rule query (the historical `fanout()` helper
/// rescanned all nodes × edges per call — a hidden O(n²) per rule).
pub struct MatchContext<'g> {
    shapes: Cow<'g, [Vec<TensorShape>]>,
    fanout: BTreeMap<PortRef, usize>,
}

fn fanout_map(g: &Graph) -> BTreeMap<PortRef, usize> {
    let mut map: BTreeMap<PortRef, usize> = BTreeMap::new();
    for (_, node) in g.nodes() {
        for inp in &node.inputs {
            *map.entry(*inp).or_default() += 1;
        }
    }
    for out in &g.outputs {
        *map.entry(*out).or_default() += 1;
    }
    map
}

impl<'g> MatchContext<'g> {
    /// Build a context, inferring shapes. Errors (instead of panicking,
    /// as the old `shapes_of` helper did) when the graph is invalid — a
    /// bad model file now reports cleanly through the CLI.
    pub fn new(g: &Graph) -> anyhow::Result<MatchContext<'static>> {
        let shapes = g
            .infer_shapes()
            .map_err(|e| anyhow::anyhow!("substitution over invalid graph: {e}"))?;
        Ok(MatchContext { shapes: Cow::Owned(shapes), fanout: fanout_map(g) })
    }

    /// Build a context around an already-inferred shape table (the search
    /// hot path: one inference per expanded graph, reused everywhere).
    pub fn with_shapes(g: &Graph, shapes: &'g [Vec<TensorShape>]) -> MatchContext<'g> {
        MatchContext { shapes: Cow::Borrowed(shapes), fanout: fanout_map(g) }
    }

    /// As [`MatchContext::with_shapes`], deriving the fanout map from an
    /// already-built consumer map (the outer search shares one per wave
    /// entry with its delta views) instead of rescanning every edge.
    /// `consumers` must be `g.consumers()` — it records one entry per
    /// input occurrence, so its lengths plus the output multiplicities
    /// are exactly the [`MatchContext::fanout`] counts.
    pub fn with_shapes_and_consumers(
        g: &Graph,
        shapes: &'g [Vec<TensorShape>],
        consumers: &BTreeMap<PortRef, Vec<NodeId>>,
    ) -> MatchContext<'g> {
        let mut fanout: BTreeMap<PortRef, usize> =
            consumers.iter().map(|(p, v)| (*p, v.len())).collect();
        for out in &g.outputs {
            *fanout.entry(*out).or_default() += 1;
        }
        MatchContext { shapes: Cow::Borrowed(shapes), fanout }
    }

    /// The graph's full shape table.
    pub fn shapes(&self) -> &[Vec<TensorShape>] {
        &self.shapes
    }

    /// How many consumers (including graph outputs, counting multiplicity)
    /// read port `p`? O(log n) lookup against the precomputed map.
    pub fn fanout(&self, p: PortRef) -> usize {
        self.fanout.get(&p).copied().unwrap_or(0)
    }
}

/// One matched rewrite opportunity: the anchor node the rule fired on plus
/// the precomputed data needed to expand it into a [`GraphDelta`].
pub struct RewriteSite {
    pub(crate) rule: &'static str,
    pub(crate) anchor: NodeId,
    pub(crate) kind: rules::SiteKind,
}

impl RewriteSite {
    /// Name of the rule that matched.
    pub fn rule_name(&self) -> &'static str {
        self.rule
    }

    /// The matched anchor node (the consumer being rewritten).
    pub fn anchor(&self) -> NodeId {
        self.anchor
    }

    /// Expand the site into the delta that performs the rewrite. `g` must
    /// be the same graph the site was found on.
    pub fn delta(&self, g: &Graph) -> GraphDelta {
        self.kind.build(g)
    }
}

/// One equivalent graph substitution `S_i`.
pub trait Rule: Send + Sync {
    /// Stable rule name (reporting and rule-set ablations).
    fn name(&self) -> &'static str;

    /// Find every site the rule matches (each site = the rule applied at
    /// exactly one place, mirroring MetaFlow's one-substitution-per-step
    /// search granularity), in deterministic anchor order.
    fn find_sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite>;

    /// Apply the rule at every matching site, returning one (uncompacted)
    /// product graph per site — the historical whole-graph API, now a
    /// materializing wrapper over `find_sites` + [`Graph::apply_delta`].
    fn apply_all(&self, g: &Graph) -> anyhow::Result<Vec<Graph>> {
        let cx = MatchContext::new(g)?;
        Ok(self.find_sites(g, &cx).iter().map(|s| g.apply_delta(&s.delta(g))).collect())
    }
}

/// The standard rule set `{S_1..S_m}` handed to the optimizer.
pub struct RuleSet {
    rules: Vec<Box<dyn Rule>>,
}

impl RuleSet {
    /// The full rule set used by the paper reproduction.
    pub fn standard() -> RuleSet {
        RuleSet {
            rules: vec![
                Box::new(rules::FuseConvRelu),
                Box::new(rules::FuseDwConvRelu),
                Box::new(rules::FuseAddRelu),
                Box::new(rules::FuseConvBn),
                Box::new(rules::FuseDwConvBn),
                Box::new(rules::MergeParallelConvs),
                Box::new(rules::EnlargeConvKernel),
                Box::new(rules::SplitConcatElim),
                Box::new(rules::ConcatSplitElim),
                Box::new(rules::FuseConvResidual),
                Box::new(rules::FuseMatMulBiasAct),
                Box::new(rules::Cse),
            ],
        }
    }

    /// No rules: the outer search degenerates to the inner search.
    pub fn empty() -> RuleSet {
        RuleSet { rules: Vec::new() }
    }

    /// A custom rule subset (leave-one-out ablations).
    pub fn with_rules(rules: Vec<Box<dyn Rule>>) -> RuleSet {
        RuleSet { rules }
    }

    /// The names of all rules, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.rules.iter().map(|r| r.name()).collect()
    }

    /// Number of rules in the set.
    pub fn len(&self) -> usize {
        self.rules.len()
    }

    /// Whether the set holds no rules.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// All rewrite sites of every rule on `g`, in (rule registration,
    /// anchor) order — the candidate order the outer search evaluates in.
    pub fn sites(&self, g: &Graph, cx: &MatchContext) -> Vec<RewriteSite> {
        let mut out = Vec::new();
        for rule in &self.rules {
            out.extend(rule.find_sites(g, cx));
        }
        out
    }

    /// As [`RuleSet::sites`], building the [`MatchContext`] internally.
    pub fn find_sites(&self, g: &Graph) -> anyhow::Result<Vec<RewriteSite>> {
        let cx = MatchContext::new(g)?;
        Ok(self.sites(g, &cx))
    }

    /// All one-substitution neighbors of `g`, compacted — the materialized
    /// view of [`RuleSet::sites`].
    ///
    /// Perf note (EXPERIMENTS.md §Perf): rule products are *not* validated
    /// here in release builds — every rule is equivalence-verified by the
    /// property suite, and the outer search validates each surviving
    /// candidate exactly once (incremental shape inference on the delta)
    /// after hash dedup, so validating here would double the dominant cost
    /// of search expansion. Debug builds still validate and panic loudly
    /// on any rule bug.
    pub fn neighbors(&self, g: &Graph) -> anyhow::Result<Vec<(Graph, &'static str)>> {
        let mut out = Vec::new();
        for site in self.find_sites(g)? {
            let mut cand = g.apply_delta(&site.delta(g));
            cand.compact();
            if cfg!(debug_assertions) {
                if let Err(e) = cand.validate() {
                    panic!("rule {} produced invalid graph: {e:?}", site.rule_name());
                }
            }
            out.push((cand, site.rule_name()));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_ruleset_nonempty() {
        let rs = RuleSet::standard();
        assert!(rs.len() >= 6);
        assert!(rs.names().contains(&"fuse_conv_relu"));
    }

    #[test]
    fn match_context_rejects_invalid_graph() {
        let mut g = Graph::new();
        // Relu with no input: shape inference fails.
        g.add(crate::graph::OpKind::Relu, Vec::new(), "r");
        g.outputs = vec![PortRef::of(NodeId(0))];
        let err = MatchContext::new(&g).unwrap_err().to_string();
        assert!(err.contains("substitution over invalid graph"), "{err}");
        assert!(RuleSet::standard().neighbors(&g).is_err());
    }
}
