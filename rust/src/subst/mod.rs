//! Equivalent graph substitutions (paper §3.1).
//!
//! A substitution `S` takes a graph, transforms a matched subgraph by a
//! rule, and produces one or more new graphs that are *equivalent*: for any
//! input tensors they produce the same output tensors. The closure of a
//! graph under a rule set is the paper's "equivalent graph space" that the
//! outer search explores.
//!
//! Every rule here is verified for semantic equivalence two ways: unit
//! tests on structure, and randomized end-to-end executions of
//! (original, substituted) pairs through the reference engine (see
//! `rust/tests/prop_invariants.rs`).

/// The concrete substitution rules (fusions, merges, eliminations).
pub mod rules;

use crate::graph::Graph;

/// One equivalent graph substitution `S_i`.
pub trait Rule: Send + Sync {
    /// Stable rule name (reporting and rule-set ablations).
    fn name(&self) -> &'static str;

    /// Apply the rule at every matching site, returning one new graph per
    /// site (each graph = the rule applied at exactly one site, mirroring
    /// MetaFlow's one-substitution-per-step search granularity).
    fn apply_all(&self, g: &Graph) -> Vec<Graph>;
}

/// The standard rule set `{S_1..S_m}` handed to the optimizer.
pub struct RuleSet {
    rules: Vec<Box<dyn Rule>>,
}

impl RuleSet {
    /// The full rule set used by the paper reproduction.
    pub fn standard() -> RuleSet {
        RuleSet {
            rules: vec![
                Box::new(rules::FuseConvRelu),
                Box::new(rules::FuseDwConvRelu),
                Box::new(rules::FuseAddRelu),
                Box::new(rules::FuseConvBn),
                Box::new(rules::FuseDwConvBn),
                Box::new(rules::MergeParallelConvs),
                Box::new(rules::EnlargeConvKernel),
                Box::new(rules::SplitConcatElim),
                Box::new(rules::ConcatSplitElim),
                Box::new(rules::FuseConvResidual),
            ],
        }
    }

    /// No rules: the outer search degenerates to the inner search.
    pub fn empty() -> RuleSet {
        RuleSet { rules: Vec::new() }
    }

    /// A custom rule subset (leave-one-out ablations).
    pub fn with_rules(rules: Vec<Box<dyn Rule>>) -> RuleSet {
        RuleSet { rules }
    }

    /// The names of all rules, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.rules.iter().map(|r| r.name()).collect()
    }

    /// Number of rules in the set.
    pub fn len(&self) -> usize {
        self.rules.len()
    }

    /// Whether the set holds no rules.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// All one-substitution neighbors of `g`, compacted.
    ///
    /// Perf note (EXPERIMENTS.md §Perf): rule products are *not* validated
    /// here in release builds — every rule is equivalence-verified by the
    /// property suite, and the outer search validates each surviving
    /// candidate exactly once (shape inference) after hash dedup, so
    /// validating here would double the dominant cost of search expansion.
    /// Debug builds still validate and panic loudly on any rule bug.
    pub fn neighbors(&self, g: &Graph) -> Vec<(Graph, &'static str)> {
        let mut out = Vec::new();
        for rule in &self.rules {
            for mut cand in rule.apply_all(g) {
                cand.compact();
                if cfg!(debug_assertions) {
                    if let Err(e) = cand.validate() {
                        panic!("rule {} produced invalid graph: {e:?}", rule.name());
                    }
                }
                out.push((cand, rule.name()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_ruleset_nonempty() {
        let rs = RuleSet::standard();
        assert!(rs.len() >= 6);
        assert!(rs.names().contains(&"fuse_conv_relu"));
    }
}
