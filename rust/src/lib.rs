//! # EADGO — Energy-Aware DNN Graph Optimization
//!
//! Reproduction of *"Energy-Aware DNN Graph Optimization"* (Wang, Ge, Qiu —
//! ReCoML @ MLSys 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! The optimizer searches the joint space of **equivalent computation
//! graphs** (via graph substitutions) and **per-node algorithm assignments**
//! (à la cuDNN's multiple convolution kernels) for the pair minimizing a
//! user cost function over inference time, energy, and power.
//!
//! Layer map:
//! - [`graph`], [`algo`], [`subst`], [`cost`], [`search`] — the paper's
//!   contribution (L3 coordinator).
//! - [`tensor`], [`energysim`], [`models`] — substrates the paper relied on
//!   (MetaFlow engine, nvidia-smi, TF model import) rebuilt from scratch.
//! - [`runtime`], [`engine`], [`profiler`] — PJRT execution of AOT-compiled
//!   JAX/Pallas artifacts (L2/L1) and measurement.
//! - [`serve`], [`report`], [`config`] — serving loop (fixed-plan and
//!   load-adaptive), paper tables, run configuration.
//! - [`util`] — offline substrates: JSON, PRNG, stats, CLI, bench harness,
//!   property testing.
//!
//! Quickstart (runs in a few hundred milliseconds on the analytic sim
//! provider — this doctest executes for real):
//! ```
//! use eadgo::prelude::*;
//! let g = eadgo::models::squeezenet::build(Default::default());
//! // Rules + a shared, thread-safe cost oracle (registry, profile DB,
//! // resolve cache, measurement provider).
//! let ctx = OptimizerContext::offline_default();
//! let objective = CostFunction::linear(0.5); // 0.5*energy + 0.5*time
//! let cfg = SearchConfig { max_dequeues: 20, ..Default::default() };
//! let result = optimize(&g, &ctx, &objective, &cfg).unwrap();
//! assert!(result.objective_value <= result.original_objective);
//! println!("energy saved: {:.1}%", 100.0 * result.energy_savings());
//! println!("search took {:.2}s over {} waves", result.stats.wall_s, result.stats.waves);
//! ```
//!
//! Parallel search: `threads: 8` evaluates candidates concurrently over the
//! shared oracle; with the deterministic sim provider the returned plan is
//! bit-identical to a sequential run (see `rust/tests/determinism.rs`):
//! ```no_run
//! use eadgo::prelude::*;
//! let g = eadgo::models::squeezenet::build(Default::default());
//! let ctx = OptimizerContext::offline_default();
//! let cfg = SearchConfig { threads: 8, ..Default::default() };
//! let result = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
//! println!("energy saved: {:.1}%", 100.0 * result.energy_savings());
//! ```
//!
//! DVFS: add the GPU core clock as a third search dimension — the joint
//! `(graph, algorithm, frequency)` optimization (`eadgo optimize --dvfs
//! per-graph` on the CLI). `PerGraph` locks one frequency state per plan;
//! `PerNode` lets every node pick its own state jointly with its
//! algorithm, so memory-bound nodes down-clock essentially for free:
//! ```no_run
//! use eadgo::prelude::*;
//! use eadgo::search::DvfsMode;
//! let g = eadgo::models::squeezenet::build(Default::default());
//! let ctx = OptimizerContext::offline_default();
//! let cfg = SearchConfig { dvfs: DvfsMode::PerGraph, ..Default::default() };
//! let result = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
//! println!(
//!     "energy saved: {:.1}% at {}",
//!     100.0 * result.energy_savings(),
//!     eadgo::report::describe_freqs(&result.assignment)
//! );
//! ```
//!
//! Pareto frontiers: [`search::optimize_frontier`] returns the whole
//! (latency, energy) trade-off as a dominance-pruned [`search::PlanFrontier`]
//! instead of a single plan, and a [`serve::ServeSession`] serves it
//! load-adaptively — energy-optimal plan under light traffic,
//! latency-optimal under pressure — optionally closing the loop with
//! measured-cost feedback, drift detection, and re-search hot-swaps
//! (`eadgo optimize --frontier N`, `eadgo serve --frontier plans.json
//! --adaptive --feedback on`):
//! ```
//! use eadgo::prelude::*;
//! let g = eadgo::models::squeezenet::build(Default::default());
//! let ctx = OptimizerContext::offline_default();
//! let cfg = SearchConfig { max_dequeues: 20, ..Default::default() };
//! let res = optimize_frontier(&g, &ctx, &cfg, 3).unwrap();
//! // Fastest-first, mutually non-dominated:
//! for pair in res.frontier.points().windows(2) {
//!     assert!(pair[0].cost.time_ms < pair[1].cost.time_ms);
//!     assert!(pair[0].cost.energy_j > pair[1].cost.energy_j);
//! }
//! ```

#![warn(missing_docs)]

/// Per-node algorithms, applicability registry, and assignments `A`.
pub mod algo;
/// Run configuration: JSON config files merged with CLI overrides.
pub mod config;
/// Cost model: node/graph costs, cost functions, profile DB, cost oracle.
pub mod cost;
/// Simulated V100 energy/power model (with DVFS states) behind profiling.
pub mod energysim;
/// Graph executors: pure-rust reference and PJRT-hybrid engines.
pub mod engine;
/// Graph IR: operators, shape inference, canonical hashing, serialization.
pub mod graph;
/// Model zoo: SqueezeNet, Inception, ResNet, MobileNet, VGG, test models.
pub mod models;
/// Cost providers: analytic sim-V100 and real CPU wallclock measurement.
pub mod profiler;
/// Report formatting and paper-table generators (Tables 1–5, frontiers).
pub mod report;
/// PJRT artifact runtime and persisted manifests (artifacts, frontiers).
pub mod runtime;
/// Two-level search: outer (graphs), inner (algorithms), constrained,
/// Pareto frontier enumeration.
pub mod search;
/// Serving loop: Poisson arrivals, dynamic batching, adaptive frontier
/// control.
pub mod serve;
/// Equivalent graph substitutions `S_i` (fusions, merges, eliminations)
/// as a two-phase delta engine (`find_sites` → `RewriteSite` →
/// `GraphDelta`).
pub mod subst;
/// Dense f32 tensors and the kernels behind the reference engine.
pub mod tensor;
/// Offline substrates: JSON, RNG, stats, CLI, bench harness, prop tests.
pub mod util;

/// Convenient re-exports of the public API surface.
pub mod prelude {
    pub use crate::algo::{Algorithm, AlgorithmRegistry, Assignment};
    pub use crate::cost::{
        CostDb, CostFunction, CostOracle, GraphCost, GraphCostTable, NodeCost, SigId,
    };
    pub use crate::energysim::{EnergyModel, FreqId, FreqState, GpuSpec};
    pub use crate::graph::{Graph, Node, OpKind, TensorShape};
    pub use crate::search::{
        optimize, optimize_frontier, DvfsMode, OptimizeResult, OptimizerContext, PlanFrontier,
        PlanPoint, SearchConfig,
    };
    pub use crate::serve::{
        AdaptiveConfig, FeedbackConfig, FrontierController, ResearchConfig, ServeConfig,
        ServeReport, ServeSession, ServiceModel,
    };
    pub use crate::subst::RuleSet;
}
