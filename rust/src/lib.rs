//! # EADGO — Energy-Aware DNN Graph Optimization
//!
//! Reproduction of *"Energy-Aware DNN Graph Optimization"* (Wang, Ge, Qiu —
//! ReCoML @ MLSys 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! The optimizer searches the joint space of **equivalent computation
//! graphs** (via graph substitutions) and **per-node algorithm assignments**
//! (à la cuDNN's multiple convolution kernels) for the pair minimizing a
//! user cost function over inference time, energy, and power.
//!
//! Layer map:
//! - [`graph`], [`algo`], [`subst`], [`cost`], [`search`] — the paper's
//!   contribution (L3 coordinator).
//! - [`tensor`], [`energysim`], [`models`] — substrates the paper relied on
//!   (MetaFlow engine, nvidia-smi, TF model import) rebuilt from scratch.
//! - [`runtime`], [`engine`], [`profiler`] — PJRT execution of AOT-compiled
//!   JAX/Pallas artifacts (L2/L1) and measurement.
//! - [`util`] — offline substrates: JSON, PRNG, stats, CLI, bench harness,
//!   property testing.
//!
//! Quickstart:
//! ```no_run
//! use eadgo::prelude::*;
//! let g = eadgo::models::squeezenet::build(Default::default());
//! // Rules + a shared, thread-safe cost oracle (registry, profile DB,
//! // resolve cache, measurement provider).
//! let ctx = OptimizerContext::offline_default();
//! let objective = CostFunction::linear(0.5); // 0.5*energy + 0.5*time
//! // threads: 8 evaluates search candidates in parallel; with the
//! // deterministic sim provider the returned plan is bit-identical to a
//! // sequential run.
//! let cfg = SearchConfig { threads: 8, ..Default::default() };
//! let result = optimize(&g, &ctx, &objective, &cfg).unwrap();
//! println!("energy saved: {:.1}%", 100.0 * result.energy_savings());
//! println!("search took {:.2}s over {} waves", result.stats.wall_s, result.stats.waves);
//! ```
//!
//! DVFS: add the GPU core clock as a third search dimension — the joint
//! `(graph, algorithm, frequency)` optimization (`eadgo optimize --dvfs
//! per-graph` on the CLI). `PerGraph` locks one frequency state per plan;
//! `PerNode` lets every node pick its own state jointly with its
//! algorithm, so memory-bound nodes down-clock essentially for free:
//! ```no_run
//! use eadgo::prelude::*;
//! use eadgo::search::DvfsMode;
//! let g = eadgo::models::squeezenet::build(Default::default());
//! let ctx = OptimizerContext::offline_default();
//! let cfg = SearchConfig { dvfs: DvfsMode::PerGraph, ..Default::default() };
//! let result = optimize(&g, &ctx, &CostFunction::Energy, &cfg).unwrap();
//! println!(
//!     "energy saved: {:.1}% at {}",
//!     100.0 * result.energy_savings(),
//!     eadgo::report::describe_freqs(&result.assignment)
//! );
//! ```

pub mod algo;
pub mod config;
pub mod cost;
pub mod energysim;
pub mod engine;
pub mod graph;
pub mod models;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod subst;
pub mod tensor;
pub mod util;

/// Convenient re-exports of the public API surface.
pub mod prelude {
    pub use crate::algo::{Algorithm, AlgorithmRegistry, Assignment};
    pub use crate::cost::{
        CostDb, CostFunction, CostOracle, GraphCost, GraphCostTable, NodeCost, SigId,
    };
    pub use crate::energysim::{EnergyModel, FreqId, FreqState, GpuSpec};
    pub use crate::graph::{Graph, Node, OpKind, TensorShape};
    pub use crate::search::{
        optimize, DvfsMode, OptimizeResult, OptimizerContext, SearchConfig,
    };
    pub use crate::subst::RuleSet;
}
