//! MobileNetV1 (Howard et al. 2017), width-scaled — the paper's §5 future
//! work ("evaluate our methods with more types of DNNs"): a depthwise-
//! separable architecture whose energy profile differs sharply from the
//! dense-conv zoo (depthwise layers are bandwidth-bound and cool).
//!
//! Block = depthwise 3×3 (+BN+ReLU) then pointwise 1×1 (+BN+ReLU).

use super::{Builder, ModelConfig};
use crate::graph::{Activation, Graph, NodeId, OpKind};

impl Builder {
    /// Depthwise conv (no activation; origin graphs keep ReLU separate).
    pub fn dwconv(
        &mut self,
        x: NodeId,
        c: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        name: &str,
    ) -> NodeId {
        let w = self.weight(&[c, 1, kernel.0, kernel.1], &format!("{name}_w"));
        self.g.add1(
            OpKind::DwConv2d { stride, pad, act: Activation::None, has_bias: false },
            &[x, w],
            name,
        )
    }

    /// dw3x3 → bn → relu (the MobileNet idiom, unfused in origin form).
    pub fn dw_bn_relu(&mut self, x: NodeId, c: usize, stride: usize, name: &str) -> NodeId {
        let d = self.dwconv(x, c, (3, 3), (stride, stride), (1, 1), name);
        let b = self.batchnorm(d, c, &format!("{name}_bn"));
        self.relu(b, &format!("{name}_relu"))
    }
}

/// One depthwise-separable block: dw3x3(s)+bn+relu, pw1x1+bn+relu.
fn ds_block(b: &mut Builder, x: NodeId, cin: usize, cout: usize, stride: usize, tag: &str) -> NodeId {
    let dw = b.dw_bn_relu(x, cin, stride, &format!("{tag}_dw"));
    b.conv_bn_relu(dw, cin, cout, (1, 1), (1, 1), (0, 0), &format!("{tag}_pw"))
}

/// Build the scaled MobileNetV1: stem conv + 13 depthwise-separable blocks.
pub fn build(cfg: ModelConfig) -> Graph {
    let mut b = Builder::new(0x3B);
    let x = b.input(&[cfg.batch, 3, cfg.resolution, cfg.resolution]);
    let stem_ch = cfg.ch(32);
    let stem = b.conv_bn_relu(x, 3, stem_ch, (3, 3), (2, 2), (1, 1), "stem");

    // (cout, stride) per published MobileNetV1 block sequence.
    let blocks: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    let mut cur = stem;
    let mut cin = stem_ch;
    for (i, (cout, stride)) in blocks.into_iter().enumerate() {
        let cout = cfg.ch(cout);
        cur = ds_block(&mut b, cur, cin, cout, stride, &format!("b{i}"));
        cin = cout;
    }
    let head = b.classifier(cur, cin, cfg.classes);
    b.finish(&[head])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::subst::Rule;

    #[test]
    fn builds_and_validates() {
        let g = build(ModelConfig::default());
        g.validate().unwrap();
        let dw = g
            .nodes()
            .filter(|(_, n)| matches!(n.op, OpKind::DwConv2d { .. }))
            .count();
        assert_eq!(dw, 13);
        let pw = g
            .nodes()
            .filter(|(_, n)| matches!(n.op, OpKind::Conv2d { .. }))
            .count();
        assert_eq!(pw, 14); // stem + 13 pointwise
    }

    #[test]
    fn dw_fusion_sites_exist() {
        let g = build(ModelConfig::default());
        assert_eq!(crate::subst::rules::FuseDwConvBn.apply_all(&g).unwrap().len(), 13);
        // relu fusion only fires after the BN is folded (bn sits between);
        // chain: fold bn first, then relu fusion becomes available.
        let folded = crate::subst::rules::FuseDwConvBn.apply_all(&g).unwrap().remove(0);
        let mut folded = folded;
        folded.compact();
        assert!(!crate::subst::rules::FuseDwConvRelu.apply_all(&folded).unwrap().is_empty());
    }

    #[test]
    fn output_shape() {
        let g = build(ModelConfig::default());
        let shapes = g.infer_shapes().unwrap();
        let out = g.outputs[0];
        assert_eq!(shapes[out.node.0][out.port], vec![1, 10]);
    }
}
