//! ResNet-50 (He et al. 2016), width-scaled.
//!
//! Bottleneck residual blocks in four stages ([3,4,6,3] like the published
//! 50-layer model), each block = 1×1 → 3×3 → 1×1 with BN after every conv
//! and an additive skip. The conv+bn folds, residual-add fusions, and
//! add+relu fusions are this model's substitution surface.

use super::{Builder, ModelConfig};
use crate::graph::{Graph, NodeId};

/// Bottleneck block: in → [1x1 c, 3x3 c, 1x1 4c] + skip. `stride` applies to
/// the 3x3 (and the projection shortcut when present).
#[allow(clippy::too_many_arguments)]
fn bottleneck(
    b: &mut Builder,
    x: NodeId,
    cin: usize,
    c: usize,
    stride: usize,
    tag: &str,
) -> (NodeId, usize) {
    let cout = 4 * c;
    let c1 = b.conv(x, cin, c, (1, 1), (1, 1), (0, 0), false, &format!("{tag}_c1"));
    let n1 = b.batchnorm(c1, c, &format!("{tag}_bn1"));
    let r1 = b.relu(n1, &format!("{tag}_r1"));

    let c2 = b.conv(r1, c, c, (3, 3), (stride, stride), (1, 1), false, &format!("{tag}_c2"));
    let n2 = b.batchnorm(c2, c, &format!("{tag}_bn2"));
    let r2 = b.relu(n2, &format!("{tag}_r2"));

    let c3 = b.conv(r2, c, cout, (1, 1), (1, 1), (0, 0), false, &format!("{tag}_c3"));
    let n3 = b.batchnorm(c3, cout, &format!("{tag}_bn3"));

    // Shortcut: identity when shapes match, 1x1 projection otherwise.
    let shortcut = if cin == cout && stride == 1 {
        x
    } else {
        let sc = b.conv(x, cin, cout, (1, 1), (stride, stride), (0, 0), false, &format!("{tag}_proj"));
        b.batchnorm(sc, cout, &format!("{tag}_projbn"))
    };
    let add = b.add(n3, shortcut, &format!("{tag}_add"));
    let out = b.relu(add, &format!("{tag}_out"));
    (out, cout)
}

/// Build the scaled ResNet-50.
pub fn build(cfg: ModelConfig) -> Graph {
    let mut b = Builder::new(0x50);
    let x = b.input(&[cfg.batch, 3, cfg.resolution, cfg.resolution]);

    // Stem: 7x7/2 conv + bn + relu + maxpool/2.
    let stem_ch = cfg.ch(64);
    let stem = b.conv_bn_relu(x, 3, stem_ch, (7, 7), (2, 2), (3, 3), "stem");
    let p = b.maxpool(stem, 3, 2, 1, "stem_pool");

    let stages: [(usize, usize, usize); 4] = [
        (cfg.ch(64), 3, 1),  // stage 1: 3 blocks, stride 1
        (cfg.ch(128), 4, 2), // stage 2
        (cfg.ch(256), 6, 2), // stage 3
        (cfg.ch(512), 3, 2), // stage 4
    ];
    let mut cur = p;
    let mut cin = stem_ch;
    for (si, (c, blocks, first_stride)) in stages.into_iter().enumerate() {
        for bi in 0..blocks {
            let stride = if bi == 0 { first_stride } else { 1 };
            let (out, cout) = bottleneck(&mut b, cur, cin, c, stride, &format!("s{si}b{bi}"));
            cur = out;
            cin = cout;
        }
    }

    let head = b.classifier(cur, cin, cfg.classes);
    b.finish(&[head])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::subst::Rule;

    #[test]
    fn builds_and_validates() {
        let g = build(ModelConfig::default());
        g.validate().unwrap();
        let convs = g
            .nodes()
            .filter(|(_, n)| matches!(n.op, crate::graph::OpKind::Conv2d { .. }))
            .count();
        // 16 blocks x 3 + 4 projections + stem = 53 (the "50" + shortcuts)
        assert_eq!(convs, 53);
    }

    #[test]
    fn output_shape() {
        let g = build(ModelConfig::default());
        let shapes = g.infer_shapes().unwrap();
        let out = g.outputs[0];
        assert_eq!(shapes[out.node.0][out.port], vec![1, 10]);
    }

    #[test]
    fn residual_fusion_sites_exist() {
        let g = build(ModelConfig::default());
        // fuse_add_relu should find every block output
        let products = crate::subst::rules::FuseAddRelu.apply_all(&g).unwrap();
        assert!(products.len() >= 16, "got {}", products.len());
        // conv+bn folds available everywhere
        let folds = crate::subst::rules::FuseConvBn.apply_all(&g).unwrap();
        assert!(folds.len() >= 50, "got {}", folds.len());
    }
}
