//! Inception-v3 (Szegedy et al. 2016), width-scaled.
//!
//! Stem + 2 Inception-A blocks + reduction + 2 Inception-B blocks (with the
//! 1×7/7×1 factorized convolutions) + classifier head. Each block is a
//! multi-branch concat — the richest merge-substitution territory of the
//! three evaluation models.

use super::{Builder, ModelConfig};
use crate::graph::{Graph, NodeId};

/// Inception-A: 1x1 | 1x1→5x5 | 1x1→3x3→3x3 | avgpool→1x1, concat.
fn block_a(b: &mut Builder, x: NodeId, cin: usize, cfg: &ModelConfig, tag: &str) -> (NodeId, usize) {
    let b1 = b.conv_bn_relu(x, cin, cfg.ch(64), (1, 1), (1, 1), (0, 0), &format!("{tag}_b1"));

    let b2a = b.conv_bn_relu(x, cin, cfg.ch(48), (1, 1), (1, 1), (0, 0), &format!("{tag}_b2a"));
    let b2 = b.conv_bn_relu(b2a, cfg.ch(48), cfg.ch(64), (5, 5), (1, 1), (2, 2), &format!("{tag}_b2b"));

    let b3a = b.conv_bn_relu(x, cin, cfg.ch(64), (1, 1), (1, 1), (0, 0), &format!("{tag}_b3a"));
    let b3b = b.conv_bn_relu(b3a, cfg.ch(64), cfg.ch(96), (3, 3), (1, 1), (1, 1), &format!("{tag}_b3b"));
    let b3 = b.conv_bn_relu(b3b, cfg.ch(96), cfg.ch(96), (3, 3), (1, 1), (1, 1), &format!("{tag}_b3c"));

    let b4p = b.avgpool(x, 3, 1, 1, &format!("{tag}_b4pool"));
    let b4 = b.conv_bn_relu(b4p, cin, cfg.ch(32), (1, 1), (1, 1), (0, 0), &format!("{tag}_b4"));

    let cat = b.concat(&[b1, b2, b3, b4], &format!("{tag}_cat"));
    (cat, cfg.ch(64) + cfg.ch(64) + cfg.ch(96) + cfg.ch(32))
}

/// Reduction-A: 3x3/2 | 1x1→3x3→3x3/2 | maxpool/2, concat.
fn reduction_a(b: &mut Builder, x: NodeId, cin: usize, cfg: &ModelConfig, tag: &str) -> (NodeId, usize) {
    let b1 = b.conv_bn_relu(x, cin, cfg.ch(384), (3, 3), (2, 2), (1, 1), &format!("{tag}_b1"));
    let b2a = b.conv_bn_relu(x, cin, cfg.ch(64), (1, 1), (1, 1), (0, 0), &format!("{tag}_b2a"));
    let b2b = b.conv_bn_relu(b2a, cfg.ch(64), cfg.ch(96), (3, 3), (1, 1), (1, 1), &format!("{tag}_b2b"));
    let b2 = b.conv_bn_relu(b2b, cfg.ch(96), cfg.ch(96), (3, 3), (2, 2), (1, 1), &format!("{tag}_b2c"));
    let b3 = b.maxpool(x, 3, 2, 1, &format!("{tag}_pool"));
    let cat = b.concat(&[b1, b2, b3], &format!("{tag}_cat"));
    (cat, cfg.ch(384) + cfg.ch(96) + cin)
}

/// Inception-B: 1x1 | 1x1→1x7→7x1 | avgpool→1x1, concat (factorized convs).
fn block_b(b: &mut Builder, x: NodeId, cin: usize, cfg: &ModelConfig, tag: &str) -> (NodeId, usize) {
    let c192 = cfg.ch(192);
    let c128 = cfg.ch(128);
    let b1 = b.conv_bn_relu(x, cin, c192, (1, 1), (1, 1), (0, 0), &format!("{tag}_b1"));

    let b2a = b.conv_bn_relu(x, cin, c128, (1, 1), (1, 1), (0, 0), &format!("{tag}_b2a"));
    let b2b = b.conv_bn_relu(b2a, c128, c128, (1, 7), (1, 1), (0, 3), &format!("{tag}_b2b"));
    let b2 = b.conv_bn_relu(b2b, c128, c192, (7, 1), (1, 1), (3, 0), &format!("{tag}_b2c"));

    let b3p = b.avgpool(x, 3, 1, 1, &format!("{tag}_b3pool"));
    let b3 = b.conv_bn_relu(b3p, cin, c192, (1, 1), (1, 1), (0, 0), &format!("{tag}_b3"));

    let cat = b.concat(&[b1, b2, b3], &format!("{tag}_cat"));
    (cat, 3 * c192)
}

/// Build the scaled Inception-v3.
pub fn build(cfg: ModelConfig) -> Graph {
    let mut b = Builder::new(0x13);
    let x = b.input(&[cfg.batch, 3, cfg.resolution, cfg.resolution]);

    // Stem (compressed): conv3x3/2 + conv3x3 + maxpool.
    let s1 = b.conv_bn_relu(x, 3, cfg.ch(32), (3, 3), (2, 2), (1, 1), "stem1");
    let s2 = b.conv_bn_relu(s1, cfg.ch(32), cfg.ch(64), (3, 3), (1, 1), (1, 1), "stem2");
    let p1 = b.maxpool(s2, 3, 2, 1, "stem_pool");

    let (a1, ch_a1) = block_a(&mut b, p1, cfg.ch(64), &cfg, "mixed1");
    let (a2, ch_a2) = block_a(&mut b, a1, ch_a1, &cfg, "mixed2");
    let (r1, ch_r1) = reduction_a(&mut b, a2, ch_a2, &cfg, "reduce1");
    let (b1, ch_b1) = block_b(&mut b, r1, ch_r1, &cfg, "mixed3");
    let (b2, ch_b2) = block_b(&mut b, b1, ch_b1, &cfg, "mixed4");

    let _ = ch_b1;
    let head = b.classifier(b2, ch_b2, cfg.classes);
    b.finish(&[head])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::subst::Rule;

    #[test]
    fn builds_and_validates() {
        let g = build(ModelConfig::default());
        g.validate().unwrap();
        let convs = g
            .nodes()
            .filter(|(_, n)| matches!(n.op, crate::graph::OpKind::Conv2d { .. }))
            .count();
        assert!(convs >= 20, "got {convs} convs");
        // every conv followed by bn: batchnorm count matches
        let bns = g
            .nodes()
            .filter(|(_, n)| matches!(n.op, crate::graph::OpKind::BatchNorm { .. }))
            .count();
        assert_eq!(bns, convs);
    }

    #[test]
    fn has_parallel_merge_sites() {
        // Inception-A's b1 (1x1) and b2a (1x1) share the block input with
        // identical attrs — MergeParallelConvs must find at least one pair.
        let g = build(ModelConfig::default());
        let products = crate::subst::rules::MergeParallelConvs
            .apply_all(&g)
            .unwrap();
        assert!(!products.is_empty());
    }

    #[test]
    fn asymmetric_kernels_shape_check() {
        let g = build(ModelConfig::default());
        let shapes = g.infer_shapes().unwrap();
        let out = g.outputs[0];
        assert_eq!(shapes[out.node.0][out.port], vec![1, 10]);
    }
}
