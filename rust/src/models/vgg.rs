//! VGG-16 (Simonyan & Zisserman 2015), width-scaled — a plain stacked-conv
//! architecture: no branches, no residuals, every conv 3×3 stride-1. The
//! Winograd-friendliest model in the zoo (every conv admits algorithm C)
//! and a useful contrast to the branchy models: the outer search has only
//! fusion work here, so gains come almost entirely from the inner search.

use super::{Builder, ModelConfig};
use crate::graph::Graph;

/// Build the scaled VGG-16 (13 conv layers + classifier head).
pub fn build(cfg: ModelConfig) -> Graph {
    let mut b = Builder::new(0x16);
    let x = b.input(&[cfg.batch, 3, cfg.resolution, cfg.resolution]);

    // (channels, convs-in-stage) per published VGG-16 configuration D.
    let stages: [(usize, usize); 5] =
        [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut cur = x;
    let mut cin = 3;
    for (si, (ch, convs)) in stages.into_iter().enumerate() {
        let cout = cfg.ch(ch);
        for vi in 0..convs {
            cur = b.conv_relu(cur, cin, cout, (3, 3), (1, 1), (1, 1), &format!("s{si}c{vi}"));
            cin = cout;
        }
        cur = b.maxpool(cur, 2, 2, 0, &format!("s{si}pool"));
    }
    let head = b.classifier(cur, cin, cfg.classes);
    b.finish(&[head])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Algorithm, AlgorithmRegistry, Assignment};

    #[test]
    fn builds_and_validates() {
        let g = build(ModelConfig::default());
        g.validate().unwrap();
        let convs = g
            .nodes()
            .filter(|(_, n)| matches!(n.op, crate::graph::OpKind::Conv2d { .. }))
            .count();
        assert_eq!(convs, 13);
    }

    #[test]
    fn every_conv_admits_winograd() {
        let g = build(ModelConfig::default());
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let shapes = g.infer_shapes().unwrap();
        for id in a.tunable_ids(&g, &reg) {
            let node = g.node(id);
            if !matches!(node.op, crate::graph::OpKind::Conv2d { .. }) {
                continue;
            }
            let in_shapes: Vec<_> = node
                .inputs
                .iter()
                .map(|p| shapes[p.node.0][p.port].clone())
                .collect();
            assert!(
                reg.applicable(&node.op, &in_shapes).contains(&Algorithm::ConvWinograd),
                "conv {} not winograd-eligible",
                node.name
            );
        }
    }
}
