//! Model zoo: graph builders for the paper's three evaluation CNNs —
//! SqueezeNet, Inception-v3, ResNet-50 — plus small test models.
//!
//! Topologies are faithful (fire modules, inception branches, bottleneck
//! residual blocks); spatial and channel scales are reduced so a 1-core CPU
//! host can profile and execute them (DESIGN.md §Hardware-Adaptation). The
//! substitution opportunities the paper's optimizer exploits are purely
//! topological and survive the scaling.

/// Transformer-style attention block (tied Q/K, biased FFN).
pub mod attention;
/// Inception-v3 (branch-and-concat modules).
pub mod inception;
/// MobileNetV1 (depthwise-separable convolutions).
pub mod mobilenet;
/// ResNet-50 (bottleneck residual blocks).
pub mod resnet;
/// Small test models: quickstart CNN and MLP.
pub mod simple;
/// SqueezeNet (fire modules).
pub mod squeezenet;
/// VGG-16 (plain conv stacks).
pub mod vgg;

use crate::graph::op::{eps_bits, WeightKind};
use crate::graph::{Activation, Graph, NodeId, OpKind, PortRef};

/// Uniform scale configuration for zoo models.
#[derive(Debug, Clone, Copy)]
pub struct ModelConfig {
    /// Batch size.
    pub batch: usize,
    /// Input spatial resolution (square).
    pub resolution: usize,
    /// Channel divisor vs the published architecture (4 = quarter width).
    pub width_div: usize,
    /// Classifier classes.
    pub classes: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { batch: 1, resolution: 32, width_div: 4, classes: 10 }
    }
}

impl ModelConfig {
    /// Scale a channel count, keeping at least 2.
    pub fn ch(&self, full: usize) -> usize {
        (full / self.width_div).max(2)
    }
}

/// Incremental graph builder with an automatic weight-seed allocator —
/// keeps zoo code terse and weights collision-free.
pub struct Builder {
    /// The graph under construction.
    pub g: Graph,
    next_seed: u64,
}

impl Builder {
    /// Start a model; `model_tag` namespaces its weight seeds.
    pub fn new(model_tag: u64) -> Builder {
        Builder { g: Graph::new(), next_seed: model_tag << 32 }
    }

    /// Allocate the next weight seed.
    pub fn seed(&mut self) -> u64 {
        self.next_seed += 1;
        self.next_seed
    }

    /// Add the graph input placeholder.
    pub fn input(&mut self, shape: &[usize]) -> NodeId {
        self.g.add1(OpKind::Input { shape: shape.to_vec() }, &[], "input")
    }

    /// Add a filter weight with an auto-allocated seed.
    pub fn weight(&mut self, shape: &[usize], name: &str) -> NodeId {
        let s = self.seed();
        self.g.add1(OpKind::weight(shape.to_vec(), s), &[], name)
    }

    fn wkind(&mut self, shape: &[usize], kind: WeightKind, name: &str) -> NodeId {
        let s = self.seed();
        self.g.add1(OpKind::weight_kind(shape.to_vec(), s, kind), &[], name)
    }

    /// Plain convolution (no activation — "origin" graphs keep ReLU as a
    /// separate node so the optimizer has fusion work to discover).
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        &mut self,
        x: NodeId,
        cin: usize,
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        bias: bool,
        name: &str,
    ) -> NodeId {
        let w = self.weight(&[cout, cin, kernel.0, kernel.1], &format!("{name}_w"));
        let mut inputs = vec![x, w];
        if bias {
            let b = self.wkind(&[cout], WeightKind::Bias, &format!("{name}_b"));
            inputs.push(b);
        }
        self.g.add1(
            OpKind::Conv2d {
                stride,
                pad,
                act: Activation::None,
                has_bias: bias,
                has_residual: false,
            },
            &inputs,
            name,
        )
    }

    /// Add a standalone ReLU.
    pub fn relu(&mut self, x: NodeId, name: &str) -> NodeId {
        self.g.add1(OpKind::Relu, &[x], name)
    }

    /// Add a BatchNorm with its four parameter tensors.
    pub fn batchnorm(&mut self, x: NodeId, c: usize, name: &str) -> NodeId {
        let gamma = self.wkind(&[c], WeightKind::Gamma, &format!("{name}_g"));
        let beta = self.wkind(&[c], WeightKind::Beta, &format!("{name}_be"));
        let mean = self.wkind(&[c], WeightKind::Mean, &format!("{name}_m"));
        let var = self.wkind(&[c], WeightKind::Var, &format!("{name}_v"));
        self.g.add1(
            OpKind::BatchNorm { eps: eps_bits(1e-5) },
            &[x, gamma, beta, mean, var],
            name,
        )
    }

    /// conv → bn → relu (the ResNet/Inception idiom, unfused in origin form).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_bn_relu(
        &mut self,
        x: NodeId,
        cin: usize,
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        name: &str,
    ) -> NodeId {
        let c = self.conv(x, cin, cout, kernel, stride, pad, false, name);
        let b = self.batchnorm(c, cout, &format!("{name}_bn"));
        self.relu(b, &format!("{name}_relu"))
    }

    /// conv (bias) → relu (the SqueezeNet idiom).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_relu(
        &mut self,
        x: NodeId,
        cin: usize,
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        name: &str,
    ) -> NodeId {
        let c = self.conv(x, cin, cout, kernel, stride, pad, true, name);
        self.relu(c, &format!("{name}_relu"))
    }

    /// Add a square max pooling.
    pub fn maxpool(&mut self, x: NodeId, k: usize, stride: usize, pad: usize, name: &str) -> NodeId {
        self.g.add1(
            OpKind::MaxPool { k: (k, k), stride: (stride, stride), pad: (pad, pad) },
            &[x],
            name,
        )
    }

    /// Add a square average pooling.
    pub fn avgpool(&mut self, x: NodeId, k: usize, stride: usize, pad: usize, name: &str) -> NodeId {
        self.g.add1(
            OpKind::AvgPool { k: (k, k), stride: (stride, stride), pad: (pad, pad) },
            &[x],
            name,
        )
    }

    /// Add a channel-axis concat.
    pub fn concat(&mut self, parts: &[NodeId], name: &str) -> NodeId {
        self.g.add1(OpKind::Concat { axis: 1 }, parts, name)
    }

    /// Add an elementwise addition (residual join).
    pub fn add(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        self.g.add1(OpKind::Add, &[a, b], name)
    }

    /// Add a global average pooling.
    pub fn global_avgpool(&mut self, x: NodeId, name: &str) -> NodeId {
        self.g.add1(OpKind::GlobalAvgPool, &[x], name)
    }

    /// gap → flatten → matmul classifier head.
    pub fn classifier(&mut self, x: NodeId, cin: usize, classes: usize) -> NodeId {
        let gap = self.global_avgpool(x, "gap");
        let flat = self.g.add1(OpKind::Flatten, &[gap], "flatten");
        let w = self.weight(&[cin, classes], "fc_w");
        let mm = self.g.add1(OpKind::matmul(), &[flat, w], "fc");
        self.g.add1(OpKind::Softmax, &[mm], "softmax")
    }

    /// Set the outputs, validate, and return the finished graph.
    pub fn finish(mut self, outputs: &[NodeId]) -> Graph {
        self.g.outputs = outputs.iter().map(|&n| PortRef::of(n)).collect();
        self.g
            .validate()
            .unwrap_or_else(|e| panic!("model builder produced invalid graph: {e}"));
        self.g
    }
}

/// Catalog lookup used by the CLI and benches.
pub fn by_name(name: &str, cfg: ModelConfig) -> Option<Graph> {
    match name {
        "squeezenet" => Some(squeezenet::build(cfg)),
        "inception" | "inceptionv3" | "inception-v3" => Some(inception::build(cfg)),
        "resnet" | "resnet50" | "resnet-50" => Some(resnet::build(cfg)),
        "mobilenet" | "mobilenetv1" => Some(mobilenet::build(cfg)),
        "vgg" | "vgg16" | "vgg-16" => Some(vgg::build(cfg)),
        "simple" | "quickstart" => Some(simple::build_cnn(cfg)),
        "mlp" => Some(simple::build_mlp(cfg)),
        "attention" | "transformer" => Some(attention::build(cfg)),
        _ => None,
    }
}

/// All zoo model names (reporting).
pub fn zoo_names() -> &'static [&'static str] {
    &["squeezenet", "inception", "resnet", "mobilenet", "vgg", "simple", "mlp", "attention"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_zoo_models_validate() {
        for name in zoo_names() {
            let g = by_name(name, ModelConfig::default()).unwrap();
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(g.runtime_node_count() > 3, "{name} too trivial");
        }
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(by_name("nope", ModelConfig::default()).is_none());
    }

    #[test]
    fn width_divisor_scales_channels() {
        let cfg = ModelConfig { width_div: 8, ..Default::default() };
        assert_eq!(cfg.ch(64), 8);
        assert_eq!(cfg.ch(8), 2); // floor at 2
    }
}
