//! A small transformer-style attention block — the zoo's matmul-heavy
//! model, built to exercise the matmul-side rewrite family:
//!
//! * Q and K projections share **tied weights** (same seed): their cones
//!   are byte-identical computations, so the `cse` rule can collapse one
//!   whole projection matmul.
//! * The FFN keeps its bias `Add`s and `Relu` as separate nodes (origin
//!   form), so `fuse_matmul_epilogue` has real sites.
//! * Dimensions mix tensor-core-friendly multiples of 8 (the model dim)
//!   with ragged sizes (the FFN hidden dim, the classifier), so the NHWC
//!   layout axis prices both sides of its matmul bytes factor.
//! * The context passes through a two-head mix stage whose second
//!   `Split` directly re-splits the first stage's `Concat` — the
//!   re-split-fused-projection pattern `concat_split_elim` cancels, so
//!   the split/concat algebra has a zoo site too.
//!
//! The attention itself is the gated (elementwise) simplification — score
//! = softmax(Q + K), context = score ⊙ V — which stays inside the
//! operator set (no transpose op) while keeping the projection/FFN
//! structure of a real block. Tensors are rank-2 `[seq, dim]` throughout.

use super::{Builder, ModelConfig};
use crate::graph::{Graph, NodeId, OpKind, PortRef};

/// Project `x` `[seq, din]` through a weight `[din, dout]`.
fn proj(b: &mut Builder, x: NodeId, din: usize, dout: usize, seed: u64, name: &str) -> NodeId {
    let w = b.g.add1(OpKind::weight(vec![din, dout], seed), &[], &format!("{name}_w"));
    b.g.add1(OpKind::matmul(), &[x, w], name)
}

/// Matmul + separate bias add (full-output-shape constant) — the unfused
/// origin idiom `fuse_matmul_epilogue` folds away.
fn linear_bias(
    b: &mut Builder,
    x: NodeId,
    seq: usize,
    din: usize,
    dout: usize,
    name: &str,
) -> NodeId {
    let w = b.weight(&[din, dout], &format!("{name}_w"));
    let mm = b.g.add1(OpKind::matmul(), &[x, w], name);
    let bias = b.weight(&[seq, dout], &format!("{name}_bias"));
    b.g.add1(OpKind::Add, &[mm, bias], &format!("{name}_add"))
}

/// Build the attention block model: tied Q/K + V projections, gated
/// attention, biased two-layer FFN with residual, classifier head.
pub fn build(cfg: ModelConfig) -> Graph {
    let mut b = Builder::new(0x0B);
    let seq = cfg.resolution; // sequence length (rank-2 model: no batch dim)
    let dim = cfg.ch(256); // multiple of 8 at the default width divisors
    let hid = cfg.ch(512) + 3; // deliberately ragged
    let x = b.input(&[seq, dim]);

    // Tied Q/K: one seed, two structurally identical projection cones.
    let qk_seed = b.seed();
    let q = proj(&mut b, x, dim, dim, qk_seed, "q");
    let k = proj(&mut b, x, dim, dim, qk_seed, "k");
    let v = b.seed();
    let v = proj(&mut b, x, dim, dim, v, "v");

    // Gated attention: score = softmax(q + k) over the last dim, applied
    // elementwise to the value projection.
    let score_pre = b.add(q, k, "score_pre");
    let score = b.g.add1(OpKind::Softmax, &[score_pre], "score");
    let ctx = b.g.add1(OpKind::Mul, &[score, v], "ctx");

    // Two-head mixing, the fused-projection idiom stacked twice: split
    // the context into heads, activate each, re-concat — and the second
    // stage immediately re-splits the merged tensor to gate each head.
    // The adjacent Concat→Split is exactly what `concat_split_elim`
    // cancels, the way it cancels re-split fused QKV projections.
    let half = dim / 2; // dim is a multiple of 8, so heads split evenly
    let heads = b.g.add1(OpKind::Split { axis: 1, sizes: vec![half, half] }, &[ctx], "heads");
    let h_a = b.g.add(OpKind::Relu, vec![PortRef { node: heads, port: 0 }], "head_a");
    let h_b = b.g.add(OpKind::Relu, vec![PortRef { node: heads, port: 1 }], "head_b");
    let mixed = b.g.add1(OpKind::Concat { axis: 1 }, &[h_a, h_b], "mixed");
    let heads2 = b.g.add1(OpKind::Split { axis: 1, sizes: vec![half, half] }, &[mixed], "heads2");
    let s_a = b.weight(&[seq, half], "head_scale_a");
    let s_b = b.weight(&[seq, half], "head_scale_b");
    let g_a =
        b.g.add(OpKind::Mul, vec![PortRef { node: heads2, port: 0 }, PortRef::of(s_a)], "gated_a");
    let g_b =
        b.g.add(OpKind::Mul, vec![PortRef { node: heads2, port: 1 }, PortRef::of(s_b)], "gated_b");
    let mix = b.g.add1(OpKind::Concat { axis: 1 }, &[g_a, g_b], "mixed2");

    // FFN with unfused bias/relu epilogues and a residual join.
    let h = linear_bias(&mut b, mix, seq, dim, hid, "ffn1");
    let h = b.relu(h, "ffn1_relu");
    let ffn = linear_bias(&mut b, h, seq, hid, dim, "ffn2");
    let res = b.add(mix, ffn, "residual");

    // Classifier head over the (ragged) class count.
    let head = linear_bias(&mut b, res, seq, dim, cfg.classes, "head");
    let sm = b.g.add1(OpKind::Softmax, &[head], "softmax");
    b.finish(&[sm])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{AlgorithmRegistry, Assignment};
    use crate::engine::ReferenceEngine;
    use crate::subst::RuleSet;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn attention_runs_end_to_end() {
        let cfg = ModelConfig { resolution: 16, ..Default::default() };
        let g = build(cfg);
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let eng = ReferenceEngine::new();
        let mut rng = Rng::seed_from(3);
        let x = Tensor::rand(&[16, 64], &mut rng, -1.0, 1.0);
        let out = eng.run(&g, &a, &[x]).unwrap();
        assert_eq!(out.outputs[0].shape(), &[16, 10]);
        // each row of the softmax head sums to 1
        let row: f32 = out.outputs[0].data()[..10].iter().sum();
        assert!((row - 1.0).abs() < 1e-4);
    }

    #[test]
    fn attention_offers_matmul_family_sites() {
        // The model must actually feed the new rules: a cse site (tied
        // Q/K) and matmul epilogue sites (FFN bias adds).
        let g = build(ModelConfig::default());
        let sites = RuleSet::standard().find_sites(&g).unwrap();
        let names: Vec<&str> = sites.iter().map(|s| s.rule_name()).collect();
        assert!(names.contains(&"cse"), "no cse site: {names:?}");
        assert!(names.contains(&"fuse_matmul_epilogue"), "no epilogue site: {names:?}");
        assert!(names.contains(&"concat_split_elim"), "no concat_split site: {names:?}");
    }
}
