//! SqueezeNet v1.1 (Iandola et al. 2016), width-scaled.
//!
//! Eight fire modules between a stem conv and a 1×1 classifier conv. Each
//! fire module = squeeze 1×1 → (expand 1×1 ‖ expand 3×3) → channel concat:
//! exactly the parallel-conv + enlargement substitution playground the
//! paper's Table 3/4/5 exercise.

use super::{Builder, ModelConfig};
use crate::graph::{Graph, NodeId};

/// One fire module. Returns the concat output and its channel count.
fn fire(
    b: &mut Builder,
    x: NodeId,
    cin: usize,
    squeeze: usize,
    expand: usize,
    tag: &str,
) -> (NodeId, usize) {
    let sq = b.conv_relu(x, cin, squeeze, (1, 1), (1, 1), (0, 0), &format!("{tag}_squeeze"));
    let e1 = b.conv_relu(sq, squeeze, expand, (1, 1), (1, 1), (0, 0), &format!("{tag}_exp1"));
    let e3 = b.conv_relu(sq, squeeze, expand, (3, 3), (1, 1), (1, 1), &format!("{tag}_exp3"));
    let cat = b.concat(&[e1, e3], &format!("{tag}_cat"));
    (cat, 2 * expand)
}

/// Build SqueezeNet v1.1 at the given scale.
pub fn build(cfg: ModelConfig) -> Graph {
    let mut b = Builder::new(0x51);
    let x = b.input(&[cfg.batch, 3, cfg.resolution, cfg.resolution]);

    // Stem: conv3x3/2 + relu + maxpool3x3/2.
    let c1_ch = cfg.ch(64);
    let c1 = b.conv_relu(x, 3, c1_ch, (3, 3), (2, 2), (1, 1), "conv1");
    let p1 = b.maxpool(c1, 3, 2, 0, "pool1");

    // Fire 2-3 (v1.1: s16 e64), then pool.
    let (f2, ch2) = fire(&mut b, p1, c1_ch, cfg.ch(16), cfg.ch(64), "fire2");
    let (f3, ch3) = fire(&mut b, f2, ch2, cfg.ch(16), cfg.ch(64), "fire3");
    let p3 = b.maxpool(f3, 3, 2, 0, "pool3");

    // Fire 4-5 (s32 e128), then pool.
    let (f4, ch4) = fire(&mut b, p3, ch3, cfg.ch(32), cfg.ch(128), "fire4");
    let (f5, ch5) = fire(&mut b, f4, ch4, cfg.ch(32), cfg.ch(128), "fire5");
    let p5 = b.maxpool(f5, 3, 2, 0, "pool5");

    // Fire 6-9 (s48 e192, s64 e256).
    let (f6, ch6) = fire(&mut b, p5, ch5, cfg.ch(48), cfg.ch(192), "fire6");
    let (f7, ch7) = fire(&mut b, f6, ch6, cfg.ch(48), cfg.ch(192), "fire7");
    let (f8, ch8) = fire(&mut b, f7, ch7, cfg.ch(64), cfg.ch(256), "fire8");
    let (f9, ch9) = fire(&mut b, f8, ch8, cfg.ch(64), cfg.ch(256), "fire9");

    // conv10 1x1 to classes + relu, then GAP + softmax head.
    let c10 = b.conv_relu(f9, ch9, cfg.classes, (1, 1), (1, 1), (0, 0), "conv10");
    let gap = b.global_avgpool(c10, "gap");
    let flat = b.g.add1(crate::graph::OpKind::Flatten, &[gap], "flatten");
    let sm = b.g.add1(crate::graph::OpKind::Softmax, &[flat], "softmax");
    b.finish(&[sm])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let g = build(ModelConfig::default());
        g.validate().unwrap();
        // 8 fire modules x 3 convs + conv1 + conv10 = 26 convolutions.
        let convs = g
            .nodes()
            .filter(|(_, n)| matches!(n.op, crate::graph::OpKind::Conv2d { .. }))
            .count();
        assert_eq!(convs, 26);
    }

    #[test]
    fn output_is_class_distribution() {
        let g = build(ModelConfig::default());
        let shapes = g.infer_shapes().unwrap();
        let out = g.outputs[0];
        assert_eq!(shapes[out.node.0][out.port], vec![1, 10]);
    }

    #[test]
    fn substitutions_available() {
        let g = build(ModelConfig::default());
        let rs = crate::subst::RuleSet::standard();
        let n = rs.neighbors(&g).unwrap();
        // conv+relu fusions at minimum (26), plus enlargement sites.
        assert!(n.len() >= 26, "only {} neighbors", n.len());
    }
}
