//! Small test models: a quickstart CNN (used by the end-to-end PJRT
//! examples — its operator set matches the AOT artifact suite) and an MLP.

use super::{Builder, ModelConfig};
use crate::graph::Graph;

/// The quickstart CNN: conv-relu → conv-relu (parallel pair) → concat →
/// maxpool → conv-relu → GAP → fc. Small enough to execute everywhere,
/// rich enough that every rule family fires.
pub fn build_cnn(cfg: ModelConfig) -> Graph {
    let mut b = Builder::new(0x05);
    let x = b.input(&[cfg.batch, 3, cfg.resolution, cfg.resolution]);
    let stem = b.conv_relu(x, 3, 8, (3, 3), (1, 1), (1, 1), "stem");
    // parallel pair on the same input (merge + enlarge targets)
    let e1 = b.conv_relu(stem, 8, 8, (1, 1), (1, 1), (0, 0), "branch1x1");
    let e3 = b.conv_relu(stem, 8, 8, (3, 3), (1, 1), (1, 1), "branch3x3");
    let cat = b.concat(&[e1, e3], "cat");
    let pool = b.maxpool(cat, 2, 2, 0, "pool");
    let c2 = b.conv_relu(pool, 16, 16, (3, 3), (1, 1), (1, 1), "conv2");
    let head = b.classifier(c2, 16, cfg.classes);
    b.finish(&[head])
}

/// A two-layer MLP on flattened input (exercises the MatMul algorithms).
pub fn build_mlp(cfg: ModelConfig) -> Graph {
    let mut b = Builder::new(0x0A);
    let features = 3 * cfg.resolution * cfg.resolution;
    let x = b.input(&[cfg.batch, 3, cfg.resolution, cfg.resolution]);
    let flat = b.g.add1(crate::graph::OpKind::Flatten, &[x], "flatten");
    let w1 = b.weight(&[features, 64], "w1");
    let h = b.g.add1(crate::graph::OpKind::matmul(), &[flat, w1], "fc1");
    let r = b.relu(h, "relu1");
    let w2 = b.weight(&[64, cfg.classes], "w2");
    let o = b.g.add1(crate::graph::OpKind::matmul(), &[r, w2], "fc2");
    let sm = b.g.add1(crate::graph::OpKind::Softmax, &[o], "softmax");
    b.finish(&[sm])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{AlgorithmRegistry, Assignment};
    use crate::engine::ReferenceEngine;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn cnn_runs_end_to_end() {
        let cfg = ModelConfig { resolution: 16, ..Default::default() };
        let g = build_cnn(cfg);
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let eng = ReferenceEngine::new();
        let mut rng = Rng::seed_from(1);
        let x = Tensor::rand(&[1, 3, 16, 16], &mut rng, -1.0, 1.0);
        let out = eng.run(&g, &a, &[x]).unwrap();
        assert_eq!(out.outputs[0].shape(), &[1, 10]);
        // softmax output sums to 1
        let s: f32 = out.outputs[0].data().iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn mlp_runs_end_to_end() {
        let cfg = ModelConfig { resolution: 8, ..Default::default() };
        let g = build_mlp(cfg);
        let reg = AlgorithmRegistry::new();
        let a = Assignment::default_for(&g, &reg);
        let eng = ReferenceEngine::new();
        let mut rng = Rng::seed_from(2);
        let x = Tensor::rand(&[1, 3, 8, 8], &mut rng, -1.0, 1.0);
        let out = eng.run(&g, &a, &[x]).unwrap();
        assert_eq!(out.outputs[0].shape(), &[1, 10]);
    }
}
