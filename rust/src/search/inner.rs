//! Inner search (paper Algorithm 2): optimize the algorithm assignment of a
//! *fixed* graph by local search in the distance-`d` neighborhood.
//!
//! ```text
//! 1: Let S be the set of all algorithm assignments of G
//! 2: Pick A ∈ S arbitrarily.
//! 3: repeat
//! 4:   noChange = true
//! 5:   for A' with distance(A', A) <= d:
//! 6:     if Cost(G, A') < Cost(G, A): A = A'; noChange = false
//! 7: until noChange
//! ```
//!
//! d=1 is plain greedy; d=2 "allows one step of downgrade"; d >= #nodes is
//! exhaustive. For additive objectives d=1 provably reaches the global
//! optimum (the cost separates per node) — property-tested against
//! exhaustive enumeration in `rust/tests/prop_invariants.rs`.
//!
//! With the DVFS axis, a per-node choice is an (algorithm, frequency)
//! pair: the searches below cover every pair across the table's frequency
//! slabs. The optimality argument is unchanged — the objective stays
//! separable per node, the per-node option set merely grows — so d=1 is
//! still globally optimal for additive objectives over the joint space. A
//! table built at the nominal clock only (one slab per node) makes this
//! bit-identical to the pre-DVFS search.
//!
//! ## The separable (additive) fast path
//!
//! For additive objectives the best (algorithm, frequency) of a node is a
//! pure function of its option rows and the objective — independent of
//! every other node and of the starting assignment. The search therefore
//! doesn't sweep at all: each node takes its **canonical per-row argmin**
//! ([`GraphCostTable::scan_argmin`] — first option attaining the strict
//! minimum, in slab-major scan order), and the final cost is one
//! [`GraphCostTable::eval`] over the result. Three compounding economies
//! ride on this, all bit-identical to the cold reference
//! (`SearchConfig::incremental_inner = false` re-derives every node,
//! memo-free, through the same canonical scan):
//!
//! - **Warm starts** ([`inner_search_incremental`] with a dirty scope):
//!   a candidate delta's untouched nodes share their rows with the parent
//!   table, so the parent's converged choice *is* their argmin — only the
//!   delta's dirty cone re-derives.
//! - **Per-row argmin memoization** ([`crate::cost::CostOracle::argmin_for`]):
//!   re-derived rows that were ever scanned under the same objective
//!   anywhere in the search answer from the memo without touching their
//!   option lists.
//! - **Indexed slabs**: the `eval`/`eval_swap` option lookups behind both
//!   paths resolve through dense per-node (algorithm, frequency) indices
//!   instead of linear scans.
//!
//! Non-additive objectives (`Power`, `Product`, d≥2) keep the literal
//! sweep of Algorithm 2 ([`inner_search`]'s general path).
//!
//! ## The boundary-aware (multi-device) path
//!
//! When the table carries a transfer overlay (`--devices gpu,dla`:
//! adjacent nodes on different devices pay a per-edge transfer cost), the
//! additive objective is separable everywhere *except* across device
//! boundaries. [`inner_search_incremental`] then routes to a dedicated
//! pass: per-row argmin initialization (the separable optimum, transfer
//! terms ignored) followed by deterministic coordinate descent through the
//! transfer-aware `eval_swap` until fixpoint. The pass is
//! start-independent, preserving the delta/full and warm/cold
//! bit-identity contracts on multi-device tables.
//!
//! The inner search is agnostic to how its table was built: the outer
//! search's delta engine assembles candidate tables by carrying untouched
//! rows over from the parent (`CostOracle::delta_table_for_freqs`), and
//! because carried rows are the very `Arc`s a full rebuild would fetch —
//! in the same compaction order — the search here walks identical numbers
//! and returns bit-identical assignments either way.

use crate::algo::Assignment;
use crate::cost::{CostFunction, CostOracle, GraphCost, GraphCostTable};
use crate::energysim::FreqId;
use crate::graph::NodeId;
use crate::util::rng::Rng;

/// Outcome of an inner search.
#[derive(Debug, Clone)]
pub struct InnerResult {
    /// The optimized per-node (algorithm, frequency) assignment.
    pub assignment: Assignment,
    /// Cost of the graph under that assignment.
    pub cost: GraphCost,
    /// Number of full neighborhood sweeps until convergence (1 for the
    /// separable fast path, which needs none).
    pub sweeps: usize,
    /// Number of per-option cost evaluations performed. Memoized argmin
    /// hits and warm-carried nodes cost zero.
    pub evals: u64,
    /// Whether the search started from a parent's converged plan (warm)
    /// rather than a cold default/arbitrary start.
    pub warm: bool,
    /// Tunable nodes (more than one option) visible to this search.
    pub nodes: u64,
    /// Tunable nodes whose choice was actually re-derived (scanned or
    /// answered by the argmin memo). A warm dirty-scoped search sweeps
    /// only the dirty cone, so `swept << nodes`.
    pub swept: u64,
}

/// Run Algorithm 2 from `start`.
///
/// Additive objectives take the separable fast path: canonical per-row
/// argmin over every node — globally optimal and **start-independent**
/// (`start` only seeds nodes the search does not touch). In exact
/// arithmetic this is precisely what the general sweep converges to from
/// the framework-default start; the per-node comparison is strictly more
/// accurate than the legacy whole-graph swap comparison near float ties
/// (a tiny per-node difference can round away inside a large graph
/// total), and ties from non-default starts resolve to the first
/// scan-order option rather than the start. Non-additive objectives run
/// the literal distance-`d` sweep from `start`. Errors on `d == 0` and
/// on swaps over invalid (node, algorithm, frequency) combinations
/// (propagated, never panicking, on the candidate-evaluation path).
pub fn inner_search(
    table: &GraphCostTable,
    cf: &CostFunction,
    d: usize,
    start: Assignment,
) -> anyhow::Result<InnerResult> {
    anyhow::ensure!(d >= 1, "inner distance must be >= 1 (got {d})");
    if cf.is_additive() {
        // d is irrelevant: per-node argmin subsumes any neighborhood
        // radius for a separable objective.
        return inner_search_incremental(table, cf, start, None, None);
    }
    let ids: Vec<NodeId> = table
        .costed_ids()
        .filter(|id| table.option_count(*id) > 1)
        .collect();
    let mut a = start;
    let mut cost = table.eval(&a);
    let mut value = cf.eval(&cost);
    let mut sweeps = 0usize;
    let mut evals = 0u64;

    loop {
        let mut changed = false;
        sweeps += 1;

        // distance-1 moves: change one node's (algorithm, frequency) pair.
        for &id in &ids {
            let current = a.get(id).unwrap();
            let current_f = a.freq(id);
            for (f, slab) in table.freq_options(id) {
                for &(algo, _) in slab.iter() {
                    if algo == current && *f == current_f {
                        continue;
                    }
                    let cand = table.eval_swap(cost, &a, id, algo, *f)?;
                    evals += 1;
                    let v = cf.eval(&cand);
                    if v < value {
                        a.set(id, algo);
                        a.set_freq(id, *f);
                        cost = cand;
                        value = v;
                        changed = true;
                    }
                }
            }
        }

        // distance-2 moves: change two nodes simultaneously (only useful for
        // non-separable objectives like Power).
        if d >= 2 {
            for i in 0..ids.len() {
                for j in (i + 1)..ids.len() {
                    let (ni, nj) = (ids[i], ids[j]);
                    let cur_i = a.get(ni).unwrap();
                    let cur_fi = a.freq(ni);
                    let cur_j = a.get(nj).unwrap();
                    let cur_fj = a.freq(nj);
                    for (fi, slab_i) in table.freq_options(ni) {
                        for &(ai, _) in slab_i.iter() {
                            for (fj, slab_j) in table.freq_options(nj) {
                                for &(aj, _) in slab_j.iter() {
                                    if ai == cur_i && *fi == cur_fi && aj == cur_j && *fj == cur_fj
                                    {
                                        continue;
                                    }
                                    let c1 = table.eval_swap(cost, &a, ni, ai, *fi)?;
                                    // second swap relative to (a with ni=ai):
                                    // the incremental delta of nj is
                                    // independent of ni.
                                    let cand = table.eval_swap(c1, &a, nj, aj, *fj)?;
                                    evals += 1;
                                    let v = cf.eval(&cand);
                                    if v < value {
                                        a.set(ni, ai);
                                        a.set_freq(ni, *fi);
                                        a.set(nj, aj);
                                        a.set_freq(nj, *fj);
                                        cost = cand;
                                        value = v;
                                        changed = true;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }

        if !changed {
            break;
        }
        // Safety valve: local search over a finite lattice always terminates
        // (strict improvement), but cap sweeps defensively.
        if sweeps > 10_000 {
            break;
        }
    }
    let n = ids.len() as u64;
    Ok(InnerResult { assignment: a, cost, sweeps, evals, warm: false, nodes: n, swept: n })
}

/// The separable (additive-objective) inner search, with the incremental
/// economies of the warm-start engine:
///
/// - `dirty: None` — **cold**: every tunable node takes its canonical
///   per-row argmin (globally optimal; the `incremental_inner = false`
///   reference when `memo` is also `None`).
/// - `dirty: Some(ids)` — **warm**: `start` must be a converged plan
///   remapped from the parent (`CandidateTable::warm`); only the listed
///   (compacted, ascending) nodes re-derive, every other node keeps the
///   parent's choice — which *is* its argmin, because its rows carried
///   over unchanged.
/// - `memo: Some(oracle)` routes re-derivations through the oracle's
///   per-row argmin memo, so shared rows scan at most once per objective
///   across the whole search (and across frontier probes at one weight).
///
/// All four combinations return bit-identical results (asserted by
/// `rust/tests/inner_incremental.rs`); they differ only in how much work
/// `evals`/`swept` record. Errors when `cf` is not additive.
pub fn inner_search_incremental(
    table: &GraphCostTable,
    cf: &CostFunction,
    start: Assignment,
    dirty: Option<&[NodeId]>,
    memo: Option<&CostOracle>,
) -> anyhow::Result<InnerResult> {
    anyhow::ensure!(
        cf.is_additive(),
        "separable inner search requires an additive objective (got {})",
        cf.describe()
    );
    if table.has_links() {
        // Multi-device table: transfer terms couple adjacent nodes, so the
        // objective is no longer separable per node and warm dirty-scoping
        // is unsound (a clean node may want to migrate because a dirty
        // neighbor did). Run the boundary-aware pass instead — it is
        // start-independent, so warm/cold and delta/full engines still
        // return bit-identical plans.
        return boundary_aware_search(table, cf, start, memo);
    }
    let mut a = start;
    let mut evals = 0u64;
    let mut nodes = 0u64;
    let mut swept = 0u64;
    for id in table.costed_ids() {
        if table.option_count(id) <= 1 {
            continue;
        }
        nodes += 1;
        if let Some(dirty) = dirty {
            // Untouched node: the warm start already holds its argmin.
            if dirty.binary_search(&id).is_err() {
                continue;
            }
        }
        swept += 1;
        let (f, algo, scanned) = match memo {
            Some(oracle) => oracle
                .argmin_for(table, id, cf)
                .expect("additive objective has an argmin key"),
            None => table.scan_argmin(id, cf),
        };
        evals += scanned;
        a.set(id, algo);
        a.set_freq(id, f);
    }
    let cost = table.eval(&a);
    Ok(InnerResult {
        assignment: a,
        cost,
        sweeps: 1,
        evals,
        warm: dirty.is_some(),
        nodes,
        swept,
    })
}

/// The transfer-aware inner search for multi-device tables (additive
/// objectives, `table.has_links()`).
///
/// Phase 1 seeds every tunable node with its **canonical per-row argmin**
/// — the node-separable optimum, ignoring transfer terms (memoizable: the
/// argmin is still a pure function of the row and the objective). Phase 2
/// repairs the boundaries with deterministic coordinate descent: sweep
/// nodes in ascending id, try every (algorithm, frequency/device) option
/// through the transfer-aware [`GraphCostTable::eval_swap`] (O(degree)
/// boundary adjustment), accept strict improvements, repeat to fixpoint.
///
/// The result is a pure function of (table, objective) — `start` only
/// seeds non-tunable nodes — which is what keeps the delta/full and
/// warm/cold engine contracts intact for multi-device tables: identical
/// tables (carried rows are shared `Arc`s, overlays edge-identical) walk
/// identical numbers. Descent over a finite lattice with strict
/// improvement always terminates; the sweep cap is a defensive valve
/// shared with the general path.
fn boundary_aware_search(
    table: &GraphCostTable,
    cf: &CostFunction,
    start: Assignment,
    memo: Option<&CostOracle>,
) -> anyhow::Result<InnerResult> {
    let ids: Vec<NodeId> = table
        .costed_ids()
        .filter(|id| table.option_count(*id) > 1)
        .collect();
    let mut a = start;
    let mut evals = 0u64;
    for &id in &ids {
        let (f, algo, scanned) = match memo {
            Some(oracle) => oracle
                .argmin_for(table, id, cf)
                .expect("additive objective has an argmin key"),
            None => table.scan_argmin(id, cf),
        };
        evals += scanned;
        a.set(id, algo);
        a.set_freq(id, f);
    }
    let mut cost = table.eval(&a);
    let mut value = cf.eval(&cost);
    let mut sweeps = 0usize;
    loop {
        sweeps += 1;
        let mut changed = false;
        for &id in &ids {
            let cur_algo = a.get(id).unwrap();
            let cur_f = a.freq(id);
            for (f, slab) in table.freq_options(id) {
                for &(algo, _) in slab.iter() {
                    if algo == cur_algo && *f == cur_f {
                        continue;
                    }
                    let cand = table.eval_swap(cost, &a, id, algo, *f)?;
                    evals += 1;
                    let v = cf.eval(&cand);
                    if v < value {
                        a.set(id, algo);
                        a.set_freq(id, *f);
                        cost = cand;
                        value = v;
                        changed = true;
                    }
                }
            }
        }
        if !changed || sweeps > 10_000 {
            break;
        }
    }
    let n = ids.len() as u64;
    Ok(InnerResult { assignment: a, cost, sweeps, evals, warm: false, nodes: n, swept: n })
}

/// Exhaustive (algorithm, frequency) enumeration (ground truth for tests;
/// exponential — guarded by `max_states`). Returns None if the space
/// exceeds the cap.
pub fn exhaustive_search(
    table: &GraphCostTable,
    cf: &CostFunction,
    start: &Assignment,
    max_states: u64,
) -> Option<InnerResult> {
    let ids: Vec<NodeId> = table
        .costed_ids()
        .filter(|id| table.option_count(*id) > 1)
        .collect();
    let mut total: u64 = 1;
    for id in &ids {
        total = total.checked_mul(table.option_count(*id) as u64)?;
        if total > max_states {
            return None;
        }
    }
    let mut best = start.clone();
    let mut best_cost = table.eval(&best);
    let mut best_val = cf.eval(&best_cost);
    let mut evals = 0u64;
    let mut counters = vec![0usize; ids.len()];
    let mut a = start.clone();
    loop {
        // materialize current counter state
        for (slot, &id) in ids.iter().enumerate() {
            let (f, algo) = table.option_nth(id, counters[slot]);
            a.set(id, algo);
            a.set_freq(id, f);
        }
        let cost = table.eval(&a);
        evals += 1;
        let v = cf.eval(&cost);
        if v < best_val {
            best = a.clone();
            best_cost = cost;
            best_val = v;
        }
        // increment odometer
        let mut slot = 0;
        loop {
            if slot == ids.len() {
                let n = ids.len() as u64;
                return Some(InnerResult {
                    assignment: best,
                    cost: best_cost,
                    sweeps: 1,
                    evals,
                    warm: false,
                    nodes: n,
                    swept: n,
                });
            }
            counters[slot] += 1;
            if counters[slot] < table.option_count(ids[slot]) {
                break;
            }
            counters[slot] = 0;
            slot += 1;
        }
    }
}

/// A uniformly random assignment over the joint (algorithm, frequency)
/// space (the paper's "pick A arbitrarily" starting point; used by
/// property tests to vary the start).
pub fn random_assignment(table: &GraphCostTable, base: &Assignment, rng: &mut Rng) -> Assignment {
    let mut a = base.clone();
    for id in table.costed_ids() {
        let n = table.option_count(id);
        if n > 1 {
            let (f, algo) = table.option_nth(id, rng.below(n));
            a.set(id, algo);
            a.set_freq(id, f);
        }
    }
    a
}

/// Pin a start assignment's frequency axis, leaving algorithms untouched —
/// the per-graph DVFS search's way of seeding one uniform state.
pub fn pinned_freq_start(base: &Assignment, freq: FreqId) -> Assignment {
    let mut a = base.clone();
    a.set_uniform_freq(freq);
    a
}
