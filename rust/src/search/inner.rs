//! Inner search (paper Algorithm 2): optimize the algorithm assignment of a
//! *fixed* graph by local search in the distance-`d` neighborhood.
//!
//! ```text
//! 1: Let S be the set of all algorithm assignments of G
//! 2: Pick A ∈ S arbitrarily.
//! 3: repeat
//! 4:   noChange = true
//! 5:   for A' with distance(A', A) <= d:
//! 6:     if Cost(G, A') < Cost(G, A): A = A'; noChange = false
//! 7: until noChange
//! ```
//!
//! d=1 is plain greedy; d=2 "allows one step of downgrade"; d >= #nodes is
//! exhaustive. For additive objectives d=1 provably reaches the global
//! optimum (the cost separates per node) — property-tested against
//! exhaustive enumeration in `rust/tests/prop_invariants.rs`.
//!
//! With the DVFS axis, a per-node choice is an (algorithm, frequency)
//! pair: the moves below enumerate every pair across the table's frequency
//! slabs. The optimality argument is unchanged — the objective stays
//! separable per node, the per-node option set merely grows — so d=1 is
//! still globally optimal for additive objectives over the joint space. A
//! table built at the nominal clock only (one slab per node) makes this
//! bit-identical to the pre-DVFS search.
//!
//! The inner search is agnostic to how its table was built: the outer
//! search's delta engine assembles candidate tables by carrying untouched
//! rows over from the parent (`CostOracle::delta_table_for_freqs`), and
//! because carried rows are the very `Arc`s a full rebuild would fetch —
//! in the same compaction order — the local search here walks identical
//! numbers and returns bit-identical assignments either way.

use crate::algo::Assignment;
use crate::cost::{CostFunction, GraphCost, GraphCostTable};
use crate::energysim::FreqId;
use crate::graph::NodeId;
use crate::util::rng::Rng;

/// Outcome of an inner search.
#[derive(Debug, Clone)]
pub struct InnerResult {
    /// The optimized per-node (algorithm, frequency) assignment.
    pub assignment: Assignment,
    /// Cost of the graph under that assignment.
    pub cost: GraphCost,
    /// Number of full neighborhood sweeps until convergence.
    pub sweeps: usize,
    /// Number of cost evaluations performed.
    pub evals: u64,
}

/// Run Algorithm 2 from `start`.
pub fn inner_search(
    table: &GraphCostTable,
    cf: &CostFunction,
    d: usize,
    start: Assignment,
) -> InnerResult {
    assert!(d >= 1, "inner distance must be >= 1");
    let ids: Vec<NodeId> = table
        .costed_ids()
        .filter(|id| table.option_count(*id) > 1)
        .collect();
    let mut a = start;
    let mut cost = table.eval(&a);
    let mut value = cf.eval(&cost);
    let mut sweeps = 0usize;
    let mut evals = 0u64;

    loop {
        let mut changed = false;
        sweeps += 1;

        // distance-1 moves: change one node's (algorithm, frequency) pair.
        for &id in &ids {
            let current = a.get(id).unwrap();
            let current_f = a.freq(id);
            for (f, slab) in table.freq_options(id) {
                for &(algo, _) in slab.iter() {
                    if algo == current && *f == current_f {
                        continue;
                    }
                    let cand = table.eval_swap(cost, &a, id, algo, *f);
                    evals += 1;
                    let v = cf.eval(&cand);
                    if v < value {
                        a.set(id, algo);
                        a.set_freq(id, *f);
                        cost = cand;
                        value = v;
                        changed = true;
                    }
                }
            }
        }

        // distance-2 moves: change two nodes simultaneously (only useful for
        // non-separable objectives like Power).
        if d >= 2 {
            for i in 0..ids.len() {
                for j in (i + 1)..ids.len() {
                    let (ni, nj) = (ids[i], ids[j]);
                    let cur_i = a.get(ni).unwrap();
                    let cur_fi = a.freq(ni);
                    let cur_j = a.get(nj).unwrap();
                    let cur_fj = a.freq(nj);
                    for (fi, slab_i) in table.freq_options(ni) {
                        for &(ai, _) in slab_i.iter() {
                            for (fj, slab_j) in table.freq_options(nj) {
                                for &(aj, _) in slab_j.iter() {
                                    if ai == cur_i && *fi == cur_fi && aj == cur_j && *fj == cur_fj
                                    {
                                        continue;
                                    }
                                    let c1 = table.eval_swap(cost, &a, ni, ai, *fi);
                                    // second swap relative to (a with ni=ai):
                                    // the incremental delta of nj is
                                    // independent of ni.
                                    let cand = table.eval_swap(c1, &a, nj, aj, *fj);
                                    evals += 1;
                                    let v = cf.eval(&cand);
                                    if v < value {
                                        a.set(ni, ai);
                                        a.set_freq(ni, *fi);
                                        a.set(nj, aj);
                                        a.set_freq(nj, *fj);
                                        cost = cand;
                                        value = v;
                                        changed = true;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }

        if !changed {
            break;
        }
        // Safety valve: local search over a finite lattice always terminates
        // (strict improvement), but cap sweeps defensively.
        if sweeps > 10_000 {
            break;
        }
    }
    InnerResult { assignment: a, cost, sweeps, evals }
}

/// Exhaustive (algorithm, frequency) enumeration (ground truth for tests;
/// exponential — guarded by `max_states`). Returns None if the space
/// exceeds the cap.
pub fn exhaustive_search(
    table: &GraphCostTable,
    cf: &CostFunction,
    start: &Assignment,
    max_states: u64,
) -> Option<InnerResult> {
    let ids: Vec<NodeId> = table
        .costed_ids()
        .filter(|id| table.option_count(*id) > 1)
        .collect();
    let mut total: u64 = 1;
    for id in &ids {
        total = total.checked_mul(table.option_count(*id) as u64)?;
        if total > max_states {
            return None;
        }
    }
    let mut best = start.clone();
    let mut best_cost = table.eval(&best);
    let mut best_val = cf.eval(&best_cost);
    let mut evals = 0u64;
    let mut counters = vec![0usize; ids.len()];
    let mut a = start.clone();
    loop {
        // materialize current counter state
        for (slot, &id) in ids.iter().enumerate() {
            let (f, algo) = table.option_nth(id, counters[slot]);
            a.set(id, algo);
            a.set_freq(id, f);
        }
        let cost = table.eval(&a);
        evals += 1;
        let v = cf.eval(&cost);
        if v < best_val {
            best = a.clone();
            best_cost = cost;
            best_val = v;
        }
        // increment odometer
        let mut slot = 0;
        loop {
            if slot == ids.len() {
                return Some(InnerResult { assignment: best, cost: best_cost, sweeps: 1, evals });
            }
            counters[slot] += 1;
            if counters[slot] < table.option_count(ids[slot]) {
                break;
            }
            counters[slot] = 0;
            slot += 1;
        }
    }
}

/// A uniformly random assignment over the joint (algorithm, frequency)
/// space (the paper's "pick A arbitrarily" starting point; used by
/// property tests to vary the start).
pub fn random_assignment(table: &GraphCostTable, base: &Assignment, rng: &mut Rng) -> Assignment {
    let mut a = base.clone();
    for id in table.costed_ids() {
        let n = table.option_count(id);
        if n > 1 {
            let (f, algo) = table.option_nth(id, rng.below(n));
            a.set(id, algo);
            a.set_freq(id, f);
        }
    }
    a
}

/// Pin a start assignment's frequency axis, leaving algorithms untouched —
/// the per-graph DVFS search's way of seeding one uniform state.
pub fn pinned_freq_start(base: &Assignment, freq: FreqId) -> Assignment {
    let mut a = base.clone();
    a.set_uniform_freq(freq);
    a
}
