//! Constrained optimization via binary search on the linear weight `w`
//! (paper §4.4): objectives like "least energy possible while inference
//! time stays under 0.7 ms" are served by searching the weight of
//! `w·E + (1-w)·T` — requiring only *pair-wise* accuracy from the cost
//! model, which the paper argues is more robust than MetaFlow's
//! value-accuracy-dependent approach.

use super::outer::{OptimizerContext, SearchConfig};
use super::{optimize, OptimizeResult};
use crate::cost::CostFunction;
use crate::graph::Graph;

/// Result of a constrained search: the chosen weight and the per-step trace.
pub struct ConstrainedResult {
    pub result: OptimizeResult,
    pub weight: f64,
    /// (w, time_ms, energy_j) for every probe, in probe order.
    pub trace: Vec<(f64, f64, f64)>,
    /// Whether the time budget was satisfiable at all.
    pub feasible: bool,
}

/// Minimize energy subject to `time_ms <= time_budget_ms`.
///
/// Larger `w` (weight on energy) yields lower energy but higher time, so we
/// binary-search the largest feasible `w`. Falls back to the best-time
/// solution when even `w = 0` misses the budget (infeasible).
pub fn optimize_with_time_budget(
    g0: &Graph,
    ctx: &OptimizerContext,
    time_budget_ms: f64,
    cfg: &SearchConfig,
    probes: usize,
) -> anyhow::Result<ConstrainedResult> {
    let mut trace = Vec::new();
    let run = |w: f64| -> anyhow::Result<OptimizeResult> {
        optimize(g0, ctx, &CostFunction::linear(w), cfg)
    };

    // Feasibility check at w = 0 (pure time objective).
    let fastest = run(0.0)?;
    trace.push((0.0, fastest.cost.time_ms, fastest.cost.energy_j));
    if fastest.cost.time_ms > time_budget_ms {
        return Ok(ConstrainedResult { result: fastest, weight: 0.0, trace, feasible: false });
    }

    let mut lo = 0.0f64; // known feasible
    let mut hi = 1.0f64; // possibly infeasible
    let mut best = fastest;
    let mut best_w = 0.0;

    // Is w = 1 already feasible? Then it is optimal for energy.
    let full = run(1.0)?;
    trace.push((1.0, full.cost.time_ms, full.cost.energy_j));
    if full.cost.time_ms <= time_budget_ms {
        return Ok(ConstrainedResult { result: full, weight: 1.0, trace, feasible: true });
    }

    for _ in 0..probes {
        let mid = 0.5 * (lo + hi);
        let res = run(mid)?;
        trace.push((mid, res.cost.time_ms, res.cost.energy_j));
        if res.cost.time_ms <= time_budget_ms {
            lo = mid;
            if res.cost.energy_j < best.cost.energy_j {
                best = res;
                best_w = mid;
            }
        } else {
            hi = mid;
        }
    }
    Ok(ConstrainedResult { result: best, weight: best_w, trace, feasible: true })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, OpKind, PortRef};

    fn graph() -> Graph {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 8, 16, 16] }, &[], "x");
        let w1 = g.add1(OpKind::weight(vec![16, 8, 3, 3], 1), &[], "w1");
        let c1 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
            &[x, w1],
            "c1",
        );
        let w2 = g.add1(OpKind::weight(vec![16, 16, 3, 3], 2), &[], "w2");
        let c2 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
            &[c1, w2],
            "c2",
        );
        g.outputs = vec![PortRef::of(c2)];
        g
    }

    #[test]
    fn generous_budget_returns_best_energy() {
        let g = graph();
        let ctx = OptimizerContext::offline_default();
        let r =
            optimize_with_time_budget(&g, &ctx, 1e9, &SearchConfig::default(), 4).unwrap();
        assert!(r.feasible);
        assert_eq!(r.weight, 1.0);
    }

    #[test]
    fn impossible_budget_reports_infeasible() {
        let g = graph();
        let ctx = OptimizerContext::offline_default();
        let r =
            optimize_with_time_budget(&g, &ctx, 1e-9, &SearchConfig::default(), 4).unwrap();
        assert!(!r.feasible);
    }

    #[test]
    fn budget_between_extremes_is_respected() {
        let g = graph();
        let ctx = OptimizerContext::offline_default();
        // budget = halfway between best-time and best-energy times
        let fast = optimize(&g, &ctx, &CostFunction::Time, &SearchConfig::default()).unwrap();
        let slow =
            optimize(&g, &ctx, &CostFunction::Energy, &SearchConfig::default()).unwrap();
        if slow.cost.time_ms > fast.cost.time_ms {
            let budget = 0.5 * (fast.cost.time_ms + slow.cost.time_ms);
            let r = optimize_with_time_budget(&g, &ctx, budget, &SearchConfig::default(), 6)
                .unwrap();
            assert!(r.feasible);
            assert!(r.result.cost.time_ms <= budget + 1e-9);
            // and no more energy than the pure-time solution
            assert!(r.result.cost.energy_j <= fast.cost.energy_j + 1e-9);
        }
    }
}
