//! Constrained optimization via binary search on the linear weight `w`
//! (paper §4.4): objectives like "least energy possible while inference
//! time stays under 0.7 ms" are served by searching the weight of
//! `w·E + (1-w)·T` — requiring only *pair-wise* accuracy from the cost
//! model, which the paper argues is more robust than MetaFlow's
//! value-accuracy-dependent approach.
//!
//! With DVFS enabled, a final **frequency refinement** pass treats the
//! clock as the cheapest lever once the budget binds (PolyThrottle,
//! arXiv:2310.19991): the searched plan's algorithms are frozen and only
//! its frequency states move — down wherever the latency headroom allows
//! (free energy on memory-bound nodes), never past the budget.
//!
//! Every probe here runs the full two-level search and therefore inherits
//! the outer search's delta candidate evaluation (`SearchConfig::
//! delta_eval`): the repeated probes of the binary search re-walk largely
//! overlapping graph neighborhoods, which is exactly where carry-over
//! cost tables and incremental hashing pay off most.

use super::outer::{DvfsMode, OptimizerContext, SearchConfig};
use super::{optimize, OptimizeResult};
use crate::algo::Assignment;
use crate::cost::{CostFunction, CostOracle, GraphCost};
use crate::energysim::{DeviceId, FreqId};
use crate::graph::Graph;

/// Result of a constrained search: the chosen weight and the per-step trace.
pub struct ConstrainedResult {
    /// The winning (feasible, or best-time fallback) optimization result.
    pub result: OptimizeResult,
    /// The linear weight on energy that produced the winner.
    pub weight: f64,
    /// (w, time_ms, energy_j) for every probe, in probe order.
    pub trace: Vec<(f64, f64, f64)>,
    /// Whether the time budget was satisfiable at all.
    pub feasible: bool,
}

/// Minimize energy subject to `time_ms <= time_budget_ms`.
///
/// Larger `w` (weight on energy) yields lower energy but higher time, so we
/// binary-search the largest feasible `w`. Falls back to the best-time
/// solution when even `w = 0` misses the budget (infeasible). With DVFS
/// enabled the feasible winner gets a final frequency-refinement pass
/// (see [`refine_frequency_to_budget`]).
pub fn optimize_with_time_budget(
    g0: &Graph,
    ctx: &OptimizerContext,
    time_budget_ms: f64,
    cfg: &SearchConfig,
    probes: usize,
) -> anyhow::Result<ConstrainedResult> {
    let mut trace = Vec::new();
    let run = |w: f64| -> anyhow::Result<OptimizeResult> {
        optimize(g0, ctx, &CostFunction::linear(w), cfg)
    };

    // Feasibility check at w = 0 (pure time objective).
    let fastest = run(0.0)?;
    trace.push((0.0, fastest.cost.time_ms, fastest.cost.energy_j));
    if fastest.cost.time_ms > time_budget_ms {
        return Ok(ConstrainedResult { result: fastest, weight: 0.0, trace, feasible: false });
    }

    let mut lo = 0.0f64; // known feasible
    let mut hi = 1.0f64; // possibly infeasible
    let mut best = fastest;
    let mut best_w = 0.0;

    // Is w = 1 already feasible? Then it is optimal for energy.
    let full = run(1.0)?;
    trace.push((1.0, full.cost.time_ms, full.cost.energy_j));
    if full.cost.time_ms <= time_budget_ms {
        return finish_constrained(ctx, cfg, time_budget_ms, full, 1.0, trace, None);
    }

    for _ in 0..probes {
        let mid = 0.5 * (lo + hi);
        let res = run(mid)?;
        trace.push((mid, res.cost.time_ms, res.cost.energy_j));
        if res.cost.time_ms <= time_budget_ms {
            lo = mid;
            if res.cost.energy_j < best.cost.energy_j {
                best = res;
                best_w = mid;
            }
        } else {
            hi = mid;
        }
    }
    finish_constrained(ctx, cfg, time_budget_ms, best, best_w, trace, Some(&full))
}

/// Final step of every feasible outcome: frequency refinement of the
/// winning plan, plus — when the energy-extreme (w=1) plan overshot the
/// budget — an attempt to pull *that* plan back inside it by raising
/// clocks (frequency as the cheapest lever when the budget binds, instead
/// of giving the low-energy algorithms up entirely).
#[allow(clippy::too_many_arguments)]
fn finish_constrained(
    ctx: &OptimizerContext,
    cfg: &SearchConfig,
    time_budget_ms: f64,
    mut result: OptimizeResult,
    weight: f64,
    trace: Vec<(f64, f64, f64)>,
    energy_extreme: Option<&OptimizeResult>,
) -> anyhow::Result<ConstrainedResult> {
    fn adopt(
        a: Assignment,
        c: GraphCost,
        result: &mut OptimizeResult,
        graph: Option<&Graph>,
        time_budget_ms: f64,
    ) {
        if c.time_ms <= time_budget_ms && c.energy_j < result.cost.energy_j {
            if let Some(g) = graph {
                result.graph = g.clone();
            }
            result.assignment = a;
            result.cost = c;
            result.objective_value = result.objective.eval(&c);
        }
    }
    if let Some(extreme) = energy_extreme {
        if let Some((a, c)) = refine_frequency_to_budget(
            &ctx.oracle,
            &extreme.graph,
            &extreme.assignment,
            time_budget_ms,
            cfg.dvfs,
            &cfg.layouts,
        )? {
            adopt(a, c, &mut result, Some(&extreme.graph), time_budget_ms);
        }
    }
    if let Some((a, c)) = refine_frequency_to_budget(
        &ctx.oracle,
        &result.graph,
        &result.assignment,
        time_budget_ms,
        cfg.dvfs,
        &cfg.layouts,
    )? {
        adopt(a, c, &mut result, None, time_budget_ms);
    }
    Ok(ConstrainedResult { result, weight, trace, feasible: true })
}

/// State refinement of a plan against a latency budget: keep the
/// algorithm assignment frozen and move only frequency/device states —
/// "frequency as the cheapest lever", generalized to "migration as the
/// cheapest feasibility lever" when the oracle carries extra devices.
///
/// - `PerGraph`: try every uniform state and keep the lowest-energy
///   feasible one.
/// - Otherwise (per-node DVFS, or `--dvfs off` with extra devices): two
///   greedy phases over the full per-node state set. If the plan
///   overshoots the budget, first take time-saving moves — each step the
///   one with the best time-saved-per-energy-added ratio; with extra
///   devices this includes migrating a node off a slow device — until
///   the plan fits (or no move saves time). Then take energy-saving
///   moves — each node the energy-minimal state (down-clock or cross-
///   device migration, transfer costs included via the overlay-aware
///   `eval_swap`) whose incremental cost keeps the plan inside the
///   budget — until a fixpoint.
///
/// Returns `None` when the state set is trivial (DVFS off with no extra
/// devices, or a stateless device) or no move can make the plan feasible;
/// otherwise the refined (assignment, cost). Deterministic: nodes in id
/// order, states in table order, strict-improvement acceptance.
pub fn refine_frequency_to_budget(
    oracle: &CostOracle,
    g: &Graph,
    a: &Assignment,
    time_budget_ms: f64,
    mode: DvfsMode,
    layouts: &[crate::energysim::Layout],
) -> anyhow::Result<Option<(Assignment, GraphCost)>> {
    // The same per-node state set the search itself ran over: nominal +
    // DVFS states (mode on) + extra-device states + NHWC variants (layout
    // axis on). A single-entry set means there is nothing to move.
    let all = super::outer::search_freqs(mode, layouts, oracle);
    refine_states_to_budget(oracle, g, a, time_budget_ms, mode, &all)
}

/// [`refine_frequency_to_budget`] over an *explicit* candidate state set
/// instead of the search's full one — the fault-tolerance path restricts
/// the set to states that survive a device loss or clock cap (contingency
/// synthesis, capped re-pricing). Semantics are otherwise identical.
pub fn refine_states_to_budget(
    oracle: &CostOracle,
    g: &Graph,
    a: &Assignment,
    time_budget_ms: f64,
    mode: DvfsMode,
    all: &[FreqId],
) -> anyhow::Result<Option<(Assignment, GraphCost)>> {
    if all.len() <= 1 {
        return Ok(None);
    }
    let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
    let (table, _) = oracle.table_for_freqs(g, &shapes, all);

    match mode {
        DvfsMode::PerGraph => {
            let mut best: Option<(Assignment, GraphCost)> = None;
            for &f in all {
                let mut af = a.clone();
                af.set_uniform_freq(f);
                let c = table.eval(&af);
                if c.time_ms <= time_budget_ms
                    && best.as_ref().is_none_or(|(_, b)| c.energy_j < b.energy_j)
                {
                    best = Some((af, c));
                }
            }
            Ok(best)
        }
        DvfsMode::PerNode | DvfsMode::Off => {
            let mut af = a.clone();
            let mut cost = table.eval(&af);
            // Phase 1 — budget binds: raise clocks, cheapest energy per
            // millisecond saved first, until the plan fits.
            while cost.time_ms > time_budget_ms {
                let mut best_move: Option<(crate::graph::NodeId, FreqId, GraphCost, f64)> = None;
                for id in table.costed_ids() {
                    let algo = af.get(id).expect("costed node unassigned");
                    let cur_f = af.freq(id);
                    for (f, slab) in table.freq_options(id) {
                        if *f == cur_f || !slab.iter().any(|(al, _)| *al == algo) {
                            continue;
                        }
                        let cand = table.eval_swap(cost, &af, id, algo, *f)?;
                        let saved = cost.time_ms - cand.time_ms;
                        if saved <= 0.0 {
                            continue;
                        }
                        let ratio = (cand.energy_j - cost.energy_j) / saved;
                        if best_move.as_ref().is_none_or(|(_, _, _, r)| ratio < *r) {
                            best_move = Some((id, *f, cand, ratio));
                        }
                    }
                }
                let Some((id, f, c, _)) = best_move else {
                    return Ok(None); // no frequency move saves time: stuck over budget
                };
                af.set_freq(id, f);
                cost = c;
            }
            // Phase 2 — headroom: lower clocks for energy, never past the
            // budget, until a fixpoint.
            loop {
                let mut changed = false;
                for id in table.costed_ids() {
                    let algo = af.get(id).expect("costed node unassigned");
                    let cur_f = af.freq(id);
                    let mut best_move: Option<(FreqId, GraphCost)> = None;
                    for (f, slab) in table.freq_options(id) {
                        if *f == cur_f || !slab.iter().any(|(al, _)| *al == algo) {
                            continue;
                        }
                        let cand = table.eval_swap(cost, &af, id, algo, *f)?;
                        let target = best_move.as_ref().map_or(cost.energy_j, |(_, b)| b.energy_j);
                        if cand.time_ms <= time_budget_ms && cand.energy_j < target {
                            best_move = Some((*f, cand));
                        }
                    }
                    if let Some((f, c)) = best_move {
                        af.set_freq(id, f);
                        cost = c;
                        changed = true;
                    }
                }
                if !changed {
                    break;
                }
            }
            // eval_swap chains leave the uniform-state metadata stale;
            // restamp it from the final plan.
            cost.freq = af.uniform_freq();
            Ok(Some((af, cost)))
        }
    }
}

/// Synthesize a single-device (GPU-only) contingency fallback for a
/// placed plan: every node pinned to a non-GPU device migrates back to
/// the GPU, then an unbounded-budget state refinement (phase 2 of
/// [`refine_states_to_budget`] — per-node energy minimization over the
/// GPU state set) picks its clocks. Used at `--save-frontier` time so a
/// `DeviceLost` fault at serve time can hot-swap to a plan that avoids
/// the dead device.
///
/// Returns `None` when the plan never leaves the GPU (it is its own
/// contingency); otherwise the migrated (assignment, cost), always
/// GPU-only.
pub fn synthesize_contingency(
    oracle: &CostOracle,
    g: &Graph,
    a: &Assignment,
    mode: DvfsMode,
) -> anyhow::Result<Option<(Assignment, GraphCost)>> {
    if !a.uses_non_gpu_device() {
        return Ok(None);
    }
    // Migrate: clear every non-GPU pin back to the GPU nominal state. The
    // layout axis is dropped with the device — a layout negotiated for an
    // accelerator has no meaning on the fallback device.
    let mut ga = a.clone();
    let ids: Vec<_> = ga.assigned_ids().collect();
    for id in ids {
        if ga.freq(id).device() != DeviceId::GPU {
            ga.set_freq(id, FreqId::NOMINAL);
        }
    }
    // The GPU-only state set, plus whatever GPU states the plan already
    // uses (so the migrated assignment is always evaluable).
    let mut states: Vec<FreqId> = super::outer::search_freqs(mode, &[], oracle)
        .into_iter()
        .filter(|f| f.device() == DeviceId::GPU)
        .collect();
    for id in ga.assigned_ids() {
        let f = ga.freq(id);
        if !states.contains(&f) {
            states.push(f);
        }
    }
    let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
    let (table, _) = oracle.table_for_freqs(g, &shapes, &states);
    let mut cost = table.eval(&ga);
    cost.freq = ga.uniform_freq();
    // Unbounded budget: phase 1 never fires, phase 2 minimizes energy.
    if let Some((ra, rc)) =
        refine_states_to_budget(oracle, g, &ga, f64::INFINITY, mode, &states)?
    {
        if rc.energy_j < cost.energy_j {
            return Ok(Some((ra, rc)));
        }
    }
    Ok(Some((ga, cost)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, OpKind, PortRef};

    fn graph() -> Graph {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 8, 16, 16] }, &[], "x");
        let w1 = g.add1(OpKind::weight(vec![16, 8, 3, 3], 1), &[], "w1");
        let c1 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
            &[x, w1],
            "c1",
        );
        let w2 = g.add1(OpKind::weight(vec![16, 16, 3, 3], 2), &[], "w2");
        let c2 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::Relu,
                has_bias: false,
                has_residual: false,
            },
            &[c1, w2],
            "c2",
        );
        g.outputs = vec![PortRef::of(c2)];
        g
    }

    #[test]
    fn generous_budget_returns_best_energy() {
        let g = graph();
        let ctx = OptimizerContext::offline_default();
        let r =
            optimize_with_time_budget(&g, &ctx, 1e9, &SearchConfig::default(), 4).unwrap();
        assert!(r.feasible);
        assert_eq!(r.weight, 1.0);
    }

    #[test]
    fn impossible_budget_reports_infeasible() {
        let g = graph();
        let ctx = OptimizerContext::offline_default();
        let r =
            optimize_with_time_budget(&g, &ctx, 1e-9, &SearchConfig::default(), 4).unwrap();
        assert!(!r.feasible);
    }

    #[test]
    fn refine_raises_clocks_when_budget_binds() {
        // An infeasible all-slow plan must be pulled back inside the
        // budget by raising clocks (phase 1), not discarded.
        let g = graph();
        let ctx = OptimizerContext::offline_default();
        let (table, _) = ctx.table_for(&g).unwrap();
        let a = Assignment::default_for(&g, ctx.reg());
        let nominal = table.eval(&a);
        let mut slow = a.clone();
        slow.set_uniform_freq(FreqId(510));
        let budget = nominal.time_ms * 1.001;
        let (ra, rc) =
            refine_frequency_to_budget(&ctx.oracle, &g, &slow, budget, DvfsMode::PerNode, &[])
                .unwrap()
                .expect("raising clocks to nominal always fits this budget");
        assert!(rc.time_ms <= budget + 1e-12, "refined {} vs budget {budget}", rc.time_ms);
        // The refined plan must have raised at least one node's clock.
        assert!(ra.freq_histogram() != slow.freq_histogram());
        // Off mode (or a DVFS-less device) refuses to refine.
        assert!(refine_frequency_to_budget(&ctx.oracle, &g, &slow, budget, DvfsMode::Off, &[])
            .unwrap()
            .is_none());
    }

    #[test]
    fn contingency_migrates_off_the_accelerator() {
        use crate::cost::{AlgorithmRegistry, CostDb};
        let oracle = CostOracle::new(
            AlgorithmRegistry::new(),
            CostDb::new(),
            Box::new(crate::profiler::SimHeteroProvider::new(7)),
        );
        let g = graph();
        let mut a = Assignment::default_for(&g, oracle.reg());
        // A GPU-only plan is its own contingency.
        assert!(synthesize_contingency(&oracle, &g, &a, DvfsMode::PerNode).unwrap().is_none());
        // Pin one node onto the DLA at its nominal state.
        let dla_nominal = oracle
            .device_freqs()
            .iter()
            .find(|(d, _)| *d == DeviceId::DLA)
            .expect("hetero provider exposes the DLA")
            .1[0];
        let id = a.assigned_ids().next().expect("graph has assignable nodes");
        a.set_freq(id, dla_nominal);
        assert!(a.uses_non_gpu_device());
        let (ca, cc) = synthesize_contingency(&oracle, &g, &a, DvfsMode::PerNode)
            .unwrap()
            .expect("a placed plan gets a contingency");
        assert!(!ca.uses_non_gpu_device(), "contingency must be single-device");
        assert!(cc.time_ms.is_finite() && cc.time_ms > 0.0);
        assert!(cc.energy_j.is_finite() && cc.energy_j > 0.0);
    }

    #[test]
    fn budget_between_extremes_is_respected() {
        let g = graph();
        let ctx = OptimizerContext::offline_default();
        // budget = halfway between best-time and best-energy times
        let fast = optimize(&g, &ctx, &CostFunction::Time, &SearchConfig::default()).unwrap();
        let slow =
            optimize(&g, &ctx, &CostFunction::Energy, &SearchConfig::default()).unwrap();
        if slow.cost.time_ms > fast.cost.time_ms {
            let budget = 0.5 * (fast.cost.time_ms + slow.cost.time_ms);
            let r = optimize_with_time_budget(&g, &ctx, budget, &SearchConfig::default(), 6)
                .unwrap();
            assert!(r.feasible);
            assert!(r.result.cost.time_ms <= budget + 1e-9);
            // and no more energy than the pure-time solution
            assert!(r.result.cost.energy_j <= fast.cost.energy_j + 1e-9);
        }
    }
}
