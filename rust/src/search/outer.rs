//! Outer search (paper Algorithm 1): α-relaxed backtracking over the
//! equivalent-graph space, calling the inner search on every candidate.
//!
//! ```text
//! A0 = innerSearch(G0);  Q = {(G0, A0)};  (Gopt, Aopt) = (G0, A0)
//! while Q != {}:
//!   (G, A) = Q.dequeue()
//!   for G' in Si(G), i in 1..m:
//!     A' = innerSearch(G')
//!     if Cost(G', A') < Cost(Gopt, Aopt): (Gopt, Aopt) = (G', A')
//!     if Cost(G', A') < α * Cost(Gopt, Aopt): Q.enqueue(G', A')
//! return (Gopt, Aopt)
//! ```
//!
//! α=1 degenerates to greedy; larger α explores more of the space at the
//! cost of search time (paper §3.3, following MetaFlow). We add the two
//! standard engineering guards MetaFlow uses: canonical-hash dedup of
//! visited graphs and a budget on dequeued states.
//!
//! ## Batched frontier expansion
//!
//! Candidate evaluation (profile → cost table → inner search) is the whole
//! cost of Algorithm 1, so the loop is organized around **waves**: pop
//! every queue entry currently inside the α-band, find all their rewrite
//! sites, dedup by (incremental) canonical hash, then evaluate the
//! surviving candidates **in parallel** (`SearchConfig::threads` workers
//! over the shared [`CostOracle`]) and merge the results in candidate
//! sequence order. Because evaluation of one candidate is independent of
//! the incumbent, and the merge applies best/enqueue updates in the same
//! deterministic order regardless of which worker finished first, the
//! returned `(graph, assignment, cost)` is **bit-identical across thread
//! counts** whenever the cost provider is deterministic (the default sim
//! provider is; real-wallclock `CpuProvider` measurements are inherently
//! noisy) — `threads: 8` is then purely a wall-clock optimization (see
//! `rust/tests/determinism.rs`).
//!
//! ## Delta candidate evaluation
//!
//! With `SearchConfig::delta_eval` (the default), candidates are never
//! materialized up front. Each wave entry computes its shape table, Merkle
//! node hashes, consumer map, cost table, and default assignment **once**;
//! every rewrite site then expands to a [`GraphDelta`] evaluated through:
//!
//! - [`crate::graph::canonical::delta_hash`] — dedup without
//!   re-canonicalizing the whole product;
//! - [`crate::graph::DeltaView`] — incremental shape inference (only the
//!   delta's cone re-infers; this doubles as candidate validation);
//! - [`CostOracle::delta_table_for_freqs`] — cost rows of untouched nodes
//!   carry over from the parent table across all DVFS frequency slabs;
//!   only touched nodes re-resolve.
//!
//! Full graphs materialize (apply_delta + compact) only for candidates
//! that improve the incumbent or enter the queue. Because carried rows are
//! the same `Arc`s a full rebuild would fetch and evaluation order is
//! unchanged, plans are **bit-identical** to the legacy full-rebuild path
//! (`delta_eval: false`, kept as the reference for A/B benches and the
//! determinism suite).
//!
//! [`GraphDelta`]: crate::graph::GraphDelta

use super::inner::{inner_search, inner_search_incremental, pinned_freq_start, InnerResult};
use crate::algo::Assignment;
use crate::cost::{CostFunction, CostOracle, DeltaBase, GraphCost, GraphCostTable};
use crate::energysim::{FreqId, Layout};
use crate::graph::canonical::{delta_hash, graph_hash, node_hashes};
use crate::graph::{DeltaView, Graph};
use crate::subst::RuleSet;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, BTreeMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex};

/// How the search treats the DVFS frequency axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DvfsMode {
    /// Nominal clock only — bit-identical to the pre-DVFS search.
    #[default]
    Off,
    /// One frequency state per candidate graph: every state is evaluated
    /// with a full inner search and the best (graph, A, f) wins. Models
    /// application-level `nvidia-smi -lgc` style locking.
    PerGraph,
    /// Frequency is a per-node decision, optimized jointly with the
    /// algorithm by the inner search (kernel-launch granularity DVFS).
    PerNode,
}

impl DvfsMode {
    /// Parse a CLI/config spec (`off`, `per-graph`, `per-node`).
    pub fn parse(spec: &str) -> anyhow::Result<DvfsMode> {
        Ok(match spec {
            "off" => DvfsMode::Off,
            "per-graph" | "per_graph" => DvfsMode::PerGraph,
            "per-node" | "per_node" => DvfsMode::PerNode,
            other => anyhow::bail!("unknown dvfs mode `{other}` (off|per-graph|per-node)"),
        })
    }

    /// Stable display name (inverse of [`DvfsMode::parse`]).
    pub fn describe(&self) -> &'static str {
        match self {
            DvfsMode::Off => "off",
            DvfsMode::PerGraph => "per-graph",
            DvfsMode::PerNode => "per-node",
        }
    }
}

/// Tuning knobs of the optimizer.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Relaxation factor α ≥ 1 (paper uses 1.05 in §4.1).
    pub alpha: f64,
    /// Inner-search neighborhood distance; `None` = the paper's
    /// recommendation (1 for linear objectives, 2 otherwise).
    pub inner_distance: Option<usize>,
    /// Enable the outer (graph substitution) search.
    pub enable_outer: bool,
    /// Enable the inner (algorithm assignment) search.
    pub enable_inner: bool,
    /// Hard cap on dequeued states (defense against α too large).
    pub max_dequeues: usize,
    /// Worker threads for candidate evaluation. `1` = sequential,
    /// `0` = one per available core. With a deterministic cost provider
    /// (the default sim) the optimized plan is bit-identical for every
    /// value; only wall-clock changes.
    pub threads: usize,
    /// DVFS frequency axis: off, one state per graph, or per node.
    pub dvfs: DvfsMode,
    /// Evaluate candidates through the incremental delta engine (`true`,
    /// the default): carry-over cost tables, incremental hash/shape
    /// updates, and materialization only for wave winners. `false` forces
    /// the legacy full-rebuild path (materialize + full table per
    /// candidate) — kept as the reference implementation for A/B
    /// throughput benches and bit-identity tests. Plans are identical
    /// either way for additive objectives (always) and for every
    /// objective when `incremental_inner` is off; a non-additive
    /// objective with `incremental_inner` on warm-starts its sweeps only
    /// on the delta engine, which may converge to a different (equally
    /// local-optimal) plan — set `incremental_inner: false` for a strict
    /// engine A/B there.
    pub delta_eval: bool,
    /// Run the inner search incrementally (`true`, the default): warm
    /// starts from the parent's converged plan with dirty-cone-only
    /// re-optimization, and per-row argmin memoization in the oracle —
    /// both exact for additive objectives, so plans are **bit-identical**
    /// to `false`, which re-derives every node memo-free through the same
    /// canonical per-row argmin (the A/B reference, same contract as
    /// `delta_eval`). For non-additive objectives `true` warm-starts the
    /// full sweep from the parent's plan (a different — typically better —
    /// local-search basin than the cold default start).
    pub incremental_inner: bool,
    /// Tensor layouts the search may assign per node. Empty (the default)
    /// or `[Layout::NCHW]` keeps the axis off — bit-identical to the
    /// pre-layout search. With NHWC included, every (device, clock) state
    /// is additionally offered in NHWC and the inner search optimizes the
    /// layout jointly with algorithm, frequency, and device, charging the
    /// re-tiling overlay at layout boundaries.
    pub layouts: Vec<Layout>,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            alpha: 1.05,
            inner_distance: None,
            enable_outer: true,
            enable_inner: true,
            max_dequeues: 2_000,
            threads: 1,
            dvfs: DvfsMode::Off,
            delta_eval: true,
            incremental_inner: true,
            layouts: Vec::new(),
        }
    }
}

impl SearchConfig {
    /// The worker count `threads` resolves to (0 = available parallelism).
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }
}

/// Per-rule statistics of one search run (reporting / ablations).
#[derive(Debug, Clone, Default)]
pub struct RuleStat {
    /// Rule name.
    pub name: String,
    /// Rewrite sites the rule matched across all waves (pre-dedup).
    pub sites: usize,
    /// Deltas accepted into the queue (inside the α-band post-eval).
    pub enqueued: usize,
    /// Net objective improvement attributed to the rule: the sum of
    /// incumbent-objective drops caused by its candidates (normalized
    /// objective units — under default normalization, 0.05 means the
    /// rule's wins cut 5% of the origin objective).
    pub objective_gain: f64,
}

/// Search statistics for reporting and ablations.
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    /// Graphs dequeued and expanded.
    pub expanded: usize,
    /// Candidate rewrite sites generated by substitutions.
    pub generated: usize,
    /// Candidates skipped because an isomorphic graph was already seen.
    pub deduped: usize,
    /// Candidates actually cost-evaluated (generated − deduped).
    pub evaluated: usize,
    /// Inner-search cost evaluations.
    pub inner_evals: u64,
    /// Per-rule site/accept/improvement statistics, sorted by rule name.
    pub rule_stats: Vec<RuleStat>,
    /// Total profile measurements triggered by new signatures.
    pub profiled: usize,
    /// Frontier waves expanded (each wave = one parallel evaluation batch).
    pub waves: usize,
    /// Worker threads used for candidate evaluation.
    pub threads: usize,
    /// Search wallclock, seconds.
    pub wall_s: f64,
    /// Inner searches warm-started from a converged parent plan.
    pub inner_warm: u64,
    /// Inner searches cold-started from a default/arbitrary assignment.
    pub inner_cold: u64,
    /// Tunable nodes visible to all inner searches (sum over runs).
    pub inner_nodes: u64,
    /// Tunable nodes actually re-derived by inner searches — warm starts
    /// sweep only the delta's dirty cone, so this stays far below
    /// `inner_nodes` under additive objectives.
    pub inner_swept: u64,
    /// Per-row argmin memo hits during this run (additive objectives).
    pub argmin_hits: u64,
    /// Per-row argmin memo misses (option-list scans) during this run.
    pub argmin_misses: u64,
}

impl SearchStats {
    /// Candidate-evaluation throughput of the search (candidates/sec) —
    /// the wave-expansion figure of merit the delta engine optimizes.
    pub fn candidates_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.evaluated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Fraction of inner-search node decisions answered without
    /// re-deriving (1 − swept/nodes); 0 when nothing ran.
    pub fn inner_carry_rate(&self) -> f64 {
        if self.inner_nodes > 0 {
            1.0 - self.inner_swept as f64 / self.inner_nodes as f64
        } else {
            0.0
        }
    }

    /// Argmin memo hit rate of this run (hits / lookups; 0 when none).
    pub fn argmin_hit_rate(&self) -> f64 {
        let total = self.argmin_hits + self.argmin_misses;
        if total > 0 {
            self.argmin_hits as f64 / total as f64
        } else {
            0.0
        }
    }

    /// Fold one inner-search outcome into the economy counters.
    fn add_inner(&mut self, r: &InnerResult) {
        self.inner_evals += r.evals;
        if r.warm {
            self.inner_warm += 1;
        } else {
            self.inner_cold += 1;
        }
        self.inner_nodes += r.nodes;
        self.inner_swept += r.swept;
    }
}

/// Result of `outer_search`.
pub struct OuterResult {
    /// The best graph found.
    pub graph: Graph,
    /// Its optimized per-node assignment.
    pub assignment: Assignment,
    /// Cost of the best (graph, assignment) pair.
    pub cost: GraphCost,
    /// Objective value of the best pair.
    pub objective_value: f64,
    /// Search statistics.
    pub stats: SearchStats,
    /// Best-so-far trajectory: every (G, A, cost) at which the incumbent
    /// improved, in discovery order (origin first). Capped at 64 entries.
    /// These are the "graphs from the search process" of the paper's
    /// Table 2.
    pub trajectory: Vec<(Graph, Assignment, GraphCost)>,
}

struct QueueEntry {
    value: f64,
    seq: usize, // FIFO tiebreak for equal costs (determinism)
    graph: Graph,
    /// The entry's converged inner-search plan (the paper enqueues (G, A)
    /// pairs) — the warm start its candidate deltas remap across
    /// compaction and re-optimize only on the dirty cone.
    assignment: Assignment,
}

impl PartialEq for QueueEntry {
    fn eq(&self, other: &Self) -> bool {
        self.value == other.value && self.seq == other.seq
    }
}
impl Eq for QueueEntry {}
impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert to pop the *cheapest* first
        // (MetaFlow's best-first backtracking), break ties FIFO.
        other
            .value
            .partial_cmp(&self.value)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The execution environment of the optimizer: the substitution rule set
/// plus a shared handle to the thread-safe [`CostOracle`] (algorithm
/// registry, profile database, resolve cache, measurement provider).
///
/// The oracle is an `Arc` so one warm cache can back optimize → serve →
/// re-optimize flows without re-profiling; clone the handle freely.
pub struct OptimizerContext {
    /// The substitution rule set defining the equivalent-graph space.
    pub rules: RuleSet,
    /// The shared thread-safe cost-evaluation service.
    pub oracle: Arc<CostOracle>,
}

impl OptimizerContext {
    /// Default context: standard rules + simulated-V100 profiles (seed 7).
    pub fn offline_default() -> OptimizerContext {
        OptimizerContext::new(
            RuleSet::standard(),
            crate::cost::CostDb::new(),
            Box::new(crate::profiler::SimV100Provider::new(7)),
        )
    }

    /// Build a context from rules + profile DB + measurement provider.
    pub fn new(
        rules: RuleSet,
        db: crate::cost::CostDb,
        provider: Box<dyn crate::profiler::CostProvider>,
    ) -> OptimizerContext {
        OptimizerContext {
            rules,
            oracle: Arc::new(CostOracle::new(crate::algo::AlgorithmRegistry::new(), db, provider)),
        }
    }

    /// Build around an existing (possibly already warm) oracle.
    pub fn with_oracle(rules: RuleSet, oracle: Arc<CostOracle>) -> OptimizerContext {
        OptimizerContext { rules, oracle }
    }

    /// The algorithm registry (delegates to the oracle).
    pub fn reg(&self) -> &crate::algo::AlgorithmRegistry {
        self.oracle.reg()
    }

    /// Profile `g` into the database and build its cost table.
    pub fn table_for(&self, g: &Graph) -> anyhow::Result<(GraphCostTable, usize)> {
        self.oracle.table_for(g)
    }
}

/// The origin graph's cost table and default-assignment cost, evaluated
/// once and reused by both `optimize` (objective normalization) and
/// `outer_search` (trajectory origin, inner-search start).
pub struct Baseline {
    /// The origin graph's cost table.
    pub table: GraphCostTable,
    /// The framework-default assignment for the origin graph.
    pub assignment: Assignment,
    /// Origin cost under the default assignment.
    pub cost: GraphCost,
    /// Profile measurements triggered while building the table.
    pub profiled: usize,
    /// Optional warm start for the origin's inner search: a converged
    /// plan for the *same* graph from a related run — frontier probes
    /// 2..N seed the previous probe's origin plan here. Exact for
    /// additive objectives (the separable search is start-independent);
    /// only used on the nominal-clock path.
    pub warm_hint: Option<Assignment>,
}

/// Evaluate the origin graph once (profile + table + default assignment).
pub fn evaluate_baseline(g0: &Graph, oracle: &CostOracle) -> anyhow::Result<Baseline> {
    let shapes = g0.infer_shapes().map_err(|e| anyhow::anyhow!("invalid input graph: {e}"))?;
    let (table, profiled) = oracle.table_for_with(g0, &shapes);
    let assignment = Assignment::default_for_with(g0, &shapes, oracle.reg());
    let cost = table.eval(&assignment);
    Ok(Baseline { table, assignment, cost, profiled, warm_hint: None })
}

/// Evaluate one **materialized** candidate graph: validate (shape
/// inference, once), profile missing signatures, inner-search (or default
/// assignment when disabled). With DVFS enabled the frequency axis is
/// optimized here too — per-graph by trying every state, per-node by
/// handing the inner search the joint (algorithm, frequency) option
/// space. This is the legacy full-rebuild path, used for the origin graph
/// and for `delta_eval: false` runs.
fn evaluate_candidate(
    g: &Graph,
    oracle: &CostOracle,
    cf: &CostFunction,
    cfg: &SearchConfig,
) -> anyhow::Result<(InnerResult, usize)> {
    // Single shape inference per candidate — this IS the validation, and
    // the profile/table/assignment steps below all reuse it (§Perf).
    let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!("invalid candidate: {e}"))?;
    let all = search_freqs(cfg.dvfs, &cfg.layouts, oracle);
    if all.len() <= 1 {
        let (table, profiled) = oracle.table_for_with(g, &shapes);
        let start = Assignment::default_for_with(g, &shapes, oracle.reg());
        let inner = run_inner(&table, start, cf, cfg, oracle, None)?;
        return Ok((inner, profiled));
    }
    if cfg.dvfs == DvfsMode::PerGraph {
        // One full inner search per state; NOMINAL goes first so ties
        // resolve to the nominal GPU clock (and the off-mode plan). Extra
        // devices contribute uniform-placement states, so every per-state
        // table stays single-device (transfer-free).
        let base = Assignment::default_for_with(g, &shapes, oracle.reg());
        let mut profiled = 0usize;
        let states = all.iter().map(|&f| {
            let (table, p) = oracle.table_for_freqs(g, &shapes, &[f]);
            profiled += p;
            (f, table)
        });
        let inner = best_state_inner(states, &base, cf, cfg, oracle)?;
        return Ok((inner, profiled));
    }
    // Per-node joint search over the whole (algorithm, frequency, device)
    // option space — the oracle attaches the transfer overlay when the
    // state set spans devices, and the inner search runs its
    // boundary-aware pass on top of the separable argmins.
    let (table, profiled) = oracle.table_for_freqs(g, &shapes, &all);
    let start = Assignment::default_for_with(g, &shapes, oracle.reg());
    let inner = run_inner(&table, start, cf, cfg, oracle, None)?;
    Ok((inner, profiled))
}

/// Evaluate one candidate **delta** against its parent's cached artifacts
/// — the incremental twin of [`evaluate_candidate`]. The candidate's cost
/// table carries untouched rows over from the parent across every DVFS
/// frequency slab; the inner search then warm-starts from the parent's
/// converged plan (remapped across compaction) and, for additive
/// objectives, re-optimizes **only the dirty cone** — every carried
/// node's choice is already its per-row argmin. Bit-identical to the cold
/// full re-derivation (`incremental_inner: false`) and to the legacy
/// full-rebuild engine.
fn evaluate_candidate_delta(
    base: &DeltaBase<'_>,
    view: &DeltaView<'_>,
    oracle: &CostOracle,
    cf: &CostFunction,
    cfg: &SearchConfig,
) -> anyhow::Result<(InnerResult, usize)> {
    let all = search_freqs(cfg.dvfs, &cfg.layouts, oracle);
    if all.len() <= 1 {
        let cand = oracle.delta_table_for_freqs(base, view, &[FreqId::NOMINAL]);
        let warm = cand.warm.as_ref().map(|w| (w, &cand.dirty[..]));
        let inner = run_inner(&cand.table, cand.assignment, cf, cfg, oracle, warm)?;
        return Ok((inner, cand.measured));
    }
    if cfg.dvfs == DvfsMode::PerGraph {
        // Resolve the candidate's dirty rows at every state once; the
        // per-state tables the legacy path built are recovered by
        // restricting the slabs (Arc clones — same rows, same order).
        // No warm start here (drop `converged` so the remap is never
        // built): the parent's converged plan is pinned to its own
        // winning state, but the per-state searches answer from the
        // argmin memo (carried restricted rows are shared Arcs), so
        // carried nodes still never re-scan.
        let base = DeltaBase { converged: None, ..*base };
        let cand = oracle.delta_table_for_freqs(&base, view, &all);
        let states = all.iter().map(|&f| (f, cand.table.restrict_to_freq(f)));
        let inner = best_state_inner(states, &cand.assignment, cf, cfg, oracle)?;
        return Ok((inner, cand.measured));
    }
    // Per-node joint (algorithm, frequency, device) search — same
    // boundary-aware inner path as the full-rebuild twin; the delta table
    // carries the parent's untouched rows and rebuilds the transfer
    // overlay edge-for-edge identical to a full build.
    let cand = oracle.delta_table_for_freqs(base, view, &all);
    let warm = cand.warm.as_ref().map(|w| (w, &cand.dirty[..]));
    let inner = run_inner(&cand.table, cand.assignment, cf, cfg, oracle, warm)?;
    Ok((inner, cand.measured))
}

/// Per-graph DVFS evaluation core: one pinned inner search per frequency
/// state — NOMINAL first, so objective ties resolve to the nominal clock
/// (and the off-mode plan) — keeping the best result and summing the
/// economy counters across states. Shared by the full-rebuild and delta
/// candidate paths so the tie-breaking contract (and with it the engines'
/// bit-identity, `rust/tests/determinism.rs`) cannot drift apart.
fn best_state_inner(
    states: impl Iterator<Item = (FreqId, GraphCostTable)>,
    start: &Assignment,
    cf: &CostFunction,
    cfg: &SearchConfig,
    oracle: &CostOracle,
) -> anyhow::Result<InnerResult> {
    let mut extra_evals = 0u64;
    let mut extra_nodes = 0u64;
    let mut extra_swept = 0u64;
    let mut best: Option<(f64, InnerResult)> = None;
    for (f, table) in states {
        let inner = run_inner(&table, pinned_freq_start(start, f), cf, cfg, oracle, None)?;
        extra_evals += inner.evals;
        extra_nodes += inner.nodes;
        extra_swept += inner.swept;
        let v = cf.eval(&inner.cost);
        if best.as_ref().is_none_or(|(bv, _)| v < *bv) {
            best = Some((v, inner));
        }
    }
    let (_, mut inner) = best.ok_or_else(|| anyhow::anyhow!("no frequency state evaluated"))?;
    inner.evals = extra_evals;
    inner.nodes = extra_nodes;
    inner.swept = extra_swept;
    Ok(inner)
}

/// Warm-start context for one inner search: the parent's converged plan
/// remapped onto the candidate, plus the candidate's dirty cone in
/// compacted ids (the only nodes an additive search must re-derive).
type Warm<'a> = (&'a Assignment, &'a [NodeId]);

/// One inner search with the configured engine: the separable fast path
/// for additive objectives (warm/dirty-scoped + memoized when
/// `incremental_inner`, cold canonical re-derivation otherwise — both
/// bit-identical), the literal Algorithm-2 sweep for non-additive ones
/// (warm-started from the parent's plan when incremental).
fn run_inner(
    table: &GraphCostTable,
    start: Assignment,
    cf: &CostFunction,
    cfg: &SearchConfig,
    oracle: &CostOracle,
    warm: Option<Warm<'_>>,
) -> anyhow::Result<InnerResult> {
    if !cfg.enable_inner {
        let cost = table.eval(&start);
        return Ok(InnerResult {
            assignment: start,
            cost,
            sweeps: 0,
            evals: 0,
            warm: false,
            nodes: 0,
            swept: 0,
        });
    }
    if cf.is_additive() {
        let memo = cfg.incremental_inner.then_some(oracle);
        if cfg.incremental_inner {
            if let Some((plan, dirty)) = warm {
                return inner_search_incremental(table, cf, plan.clone(), Some(dirty), memo);
            }
        }
        return inner_search_incremental(table, cf, start, None, memo);
    }
    let d = cfg.inner_distance.unwrap_or_else(|| cf.recommended_inner_distance());
    match warm {
        Some((plan, _)) if cfg.incremental_inner => {
            // Non-additive: full sweep, but from the parent's converged
            // plan — a warmer basin than the cold default.
            let mut r = inner_search(table, cf, d, plan.clone())?;
            r.warm = true;
            Ok(r)
        }
        _ => inner_search(table, cf, d, start),
    }
}

type EvalOutcome = anyhow::Result<(InnerResult, usize)>;

/// The search's frequency/placement state set: the GPU nominal clock,
/// plus the GPU DVFS states when the frequency axis is on, plus — when the
/// oracle carries extra devices (`--devices gpu,dla`) — each device's
/// packed states (nominal always; sub-nominal clocks only with DVFS on,
/// so `--dvfs off --devices gpu,dla` searches pure placement at nominal
/// clocks), plus — when `layouts` includes NHWC (`--layouts nchw,nhwc`) —
/// every base state again in NHWC, appended **after** all base states so
/// the NCHW prefix is exactly the layout-off set and ties keep resolving
/// to NCHW. One home for the list — parent carry-over tables, candidate
/// delta evaluation, and the legacy rebuild path must all build at the
/// same set, or the oracle's carry-over would silently fall back to
/// per-row re-resolves.
pub(crate) fn search_freqs(
    dvfs: DvfsMode,
    layouts: &[Layout],
    oracle: &CostOracle,
) -> Vec<FreqId> {
    let mut freqs = vec![FreqId::NOMINAL];
    if dvfs != DvfsMode::Off {
        freqs.extend_from_slice(oracle.dvfs_freqs());
    }
    for (_, dev_freqs) in oracle.device_freqs() {
        if dvfs == DvfsMode::Off {
            // Device nominal only: placement without the frequency axis.
            freqs.push(dev_freqs[0]);
        } else {
            freqs.extend_from_slice(dev_freqs);
        }
    }
    if layouts.contains(&Layout::NHWC) {
        let nhwc: Vec<FreqId> =
            freqs.iter().map(|f| f.with_layout(Layout::NHWC)).collect();
        freqs.extend(nhwc);
    }
    freqs
}

/// The frequency/placement component of the candidate dedup identity: a
/// hash of the search's DVFS mode and its full state set (GPU DVFS states
/// plus any extra-device states). Mixing it into the visited-set key means
/// a graph seen under one search space can never be conflated with the
/// same graph under another. It is deliberately NOT per-parent-state:
/// candidate evaluation is frequency-context-free (each candidate
/// re-derives its own best states from scratch), so within one run the
/// component is constant and every graph is evaluated exactly once. With
/// a single-device oracle the folded set is exactly the pre-placement
/// one — packed device bits are all zero — so dedup decisions are
/// bit-for-bit unchanged.
fn freq_domain_hash(cfg: &SearchConfig, oracle: &CostOracle) -> u64 {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;
    let mix = |h: u64, x: u64| (h ^ x).wrapping_mul(FNV_PRIME);
    let mode = match cfg.dvfs {
        DvfsMode::Off => 0u64,
        DvfsMode::PerGraph => 1,
        DvfsMode::PerNode => 2,
    };
    let mut h = mix(0xCBF2_9CE4_8422_2325, mode);
    // skip(1) drops the leading NOMINAL — with no extra devices this folds
    // exactly `oracle.dvfs_freqs()` (the historical keying, unchanged).
    for f in search_freqs(cfg.dvfs, &cfg.layouts, oracle).iter().skip(1) {
        h = mix(h, f.0 as u64);
    }
    h
}

/// Candidate dedup identity: canonical hash ⊕ frequency domain, mixed
/// with the candidate's live node count. The Merkle hash is
/// duplication-insensitive — a `cse` product hashes identically to its
/// parent (same computation) while implementing it with fewer nodes — so
/// the size rides along to keep cheaper de-duplicated variants evaluable.
/// For every other rule equal hashes imply equal compacted graphs, hence
/// equal counts: their dedup decisions are bit-for-bit unchanged.
fn dedup_key(h: u64, freq_domain: u64, live_nodes: usize) -> u64 {
    let mut f = crate::graph::canonical::Fnv::default();
    f.write_u64(h ^ freq_domain);
    f.write_usize(live_nodes);
    f.finish()
}

/// Run `eval(i)` for `i in 0..n`, in parallel when `workers > 1`. The
/// returned vector is index-aligned regardless of which worker evaluated
/// which index.
fn run_parallel<F>(n: usize, workers: usize, eval: F) -> Vec<EvalOutcome>
where
    F: Fn(usize) -> EvalOutcome + Sync,
{
    if workers <= 1 || n <= 1 {
        return (0..n).map(eval).collect();
    }
    let slots: Vec<Mutex<Option<EvalOutcome>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, AtomicOrdering::Relaxed);
                if i >= n {
                    break;
                }
                let outcome = eval(i);
                *slots[i].lock().unwrap() = Some(outcome);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("every wave slot evaluated"))
        .collect()
}

/// Algorithm 1 over batched frontier waves. `cf` must already be
/// normalized if desired; `baseline` is the origin evaluation from
/// [`evaluate_baseline`] (computed once by the caller — see `optimize`).
pub fn outer_search(
    g0: &Graph,
    ctx: &OptimizerContext,
    cf: &CostFunction,
    cfg: &SearchConfig,
    baseline: &Baseline,
) -> anyhow::Result<OuterResult> {
    let t_start = std::time::Instant::now();
    let oracle = &*ctx.oracle;
    let workers = cfg.effective_threads().max(1);
    let mut stats = SearchStats { threads: workers, ..Default::default() };
    let argmin0 = oracle.argmin_stats();
    // (sites, enqueued, objective gain) per rule, name-ordered.
    let mut rule_acc: BTreeMap<&'static str, (usize, usize, f64)> = BTreeMap::new();

    // The frequency/placement state set this run searches over — shared
    // by the origin evaluation, candidate tables, and the dedup keying.
    let mode_freqs = search_freqs(cfg.dvfs, &cfg.layouts, oracle);
    // Inner search on the origin reuses the baseline table: no second
    // profile/table pass for g0. With DVFS or extra devices enabled the
    // origin gets the full state-aware evaluation instead, so the
    // untransformed graph competes on the same (G, A, f, device) footing
    // as every candidate. A frontier probe's warm hint (the previous
    // probe's origin plan) seeds the start — result-neutral for additive
    // objectives, but it lets the economy counters attribute the origin
    // run correctly.
    let inner0 = if mode_freqs.len() <= 1 {
        // The hint only applies when an incremental inner search will
        // actually run — with the inner search disabled the start IS the
        // plan, and a hint would leak the previous probe's choices into
        // it (breaking the incremental on/off bit-identity contract).
        let use_hint = cfg.incremental_inner && cfg.enable_inner;
        let start = match (&baseline.warm_hint, use_hint) {
            (Some(hint), true) => hint.clone(),
            _ => baseline.assignment.clone(),
        };
        let mut r = run_inner(&baseline.table, start, cf, cfg, oracle, None)?;
        r.warm = baseline.warm_hint.is_some() && use_hint;
        r
    } else {
        let (inner, profiled) = evaluate_candidate(g0, oracle, cf, cfg)?;
        stats.profiled += profiled;
        inner
    };
    stats.add_inner(&inner0);

    let mut best_graph = g0.clone();
    let mut best_assignment = inner0.assignment.clone();
    let mut best_cost = inner0.cost;
    let mut best_value = cf.eval(&best_cost);
    let mut trajectory: Vec<(Graph, Assignment, GraphCost)> = Vec::new();
    // Origin with its default assignment is the first trajectory point.
    trajectory.push((g0.clone(), baseline.assignment.clone(), baseline.cost));
    if inner0.assignment != baseline.assignment {
        trajectory.push((g0.clone(), inner0.assignment.clone(), inner0.cost));
    }

    if cfg.enable_outer && !ctx.rules.is_empty() {
        let freq_domain = freq_domain_hash(cfg, oracle);
        // Wave 1 holds exactly the origin, whose carry-over base (table +
        // default assignment) the Baseline already built when the
        // frequency sets coincide — seed it instead of rebuilding.
        let mut origin_base = (cfg.delta_eval && mode_freqs.len() == 1)
            .then(|| (baseline.table.clone(), baseline.assignment.clone()));
        let mut seen: HashSet<u64> = HashSet::new();
        seen.insert(dedup_key(graph_hash(g0), freq_domain, g0.len()));
        let mut queue: BinaryHeap<QueueEntry> = BinaryHeap::new();
        let mut seq = 0usize;
        queue.push(QueueEntry {
            value: cf.eval(&inner0.cost),
            seq,
            graph: g0.clone(),
            assignment: inner0.assignment,
        });

        loop {
            // --- Pop one wave: every queued entry inside the α-band, up
            // to the remaining dequeue budget. The band test uses the
            // incumbent as of wave start, so wave composition does not
            // depend on evaluation order (or thread count).
            let mut wave: Vec<QueueEntry> = Vec::new();
            while stats.expanded < cfg.max_dequeues {
                let Some(entry) = queue.pop() else { break };
                // Backtracking prune: entries enqueued before `best`
                // improved may now fall outside the α-band — drop on pop.
                if entry.value >= cfg.alpha * best_value && entry.value > best_value {
                    continue;
                }
                stats.expanded += 1;
                wave.push(entry);
            }
            if wave.is_empty() {
                break;
            }
            stats.waves += 1;

            // --- Per-entry expansion artifacts, computed once and shared
            // by every candidate site of that entry: shape table, Merkle
            // node hashes, consumer map, and (delta mode) the parent cost
            // table + default assignment the carry-over reads from.
            let mut entry_shapes = Vec::with_capacity(wave.len());
            for entry in &wave {
                let shapes = entry
                    .graph
                    .infer_shapes()
                    .map_err(|e| anyhow::anyhow!("invalid graph in queue: {e}"))?;
                entry_shapes.push(shapes);
            }
            // Parent cost tables + default assignments (the delta
            // carry-over sources), built lazily when an entry's first
            // candidate survives dedup — an entry whose sites are all
            // already seen never pays a table walk.
            let mut entry_cost: Vec<Option<(GraphCostTable, Assignment)>> =
                (0..wave.len()).map(|_| None).collect();

            // --- Find all rewrite sites, dedup by incremental canonical
            // hash + frequency domain (sequential: order defines candidate
            // sequence numbers).
            struct PendingCand<'a> {
                parent: usize,
                rule: &'static str,
                view: DeltaView<'a>,
                graph: Option<Graph>,
            }
            let mut cands: Vec<PendingCand<'_>> = Vec::new();
            for (pi, entry) in wave.iter().enumerate() {
                let g = &entry.graph;
                let shapes = &entry_shapes[pi];
                let hashes = node_hashes(g)
                    .ok_or_else(|| anyhow::anyhow!("cyclic graph in queue"))?;
                let consumers = g.consumers();
                let cx =
                    crate::subst::MatchContext::with_shapes_and_consumers(g, shapes, &consumers);
                for site in ctx.rules.sites(g, &cx) {
                    stats.generated += 1;
                    rule_acc.entry(site.rule_name()).or_default().0 += 1;
                    let delta = site.delta(g);
                    // The view is built before dedup because delta_hash
                    // needs its remapping/liveness/topo either way; the
                    // only pre-dedup work a duplicate wastes is the shape
                    // pass, which touches the delta's dirty cone only (a
                    // handful of nodes), not the graph.
                    let view = DeltaView::new(g, shapes, delta, Some(&consumers))?;
                    let h = delta_hash(&view, &hashes);
                    if !seen.insert(dedup_key(h, freq_domain, view.live_count())) {
                        stats.deduped += 1;
                        continue;
                    }
                    if cfg.delta_eval && entry_cost[pi].is_none() {
                        // Wave 1's single entry is the origin clone.
                        if stats.waves == 1 && origin_base.is_some() {
                            entry_cost[pi] = origin_base.take();
                        } else {
                            let (table, p) = oracle.table_for_freqs(g, shapes, &mode_freqs);
                            stats.profiled += p;
                            let a = Assignment::default_for_with(g, shapes, oracle.reg());
                            entry_cost[pi] = Some((table, a));
                        }
                    }
                    // Materialize up front only for the legacy full-rebuild
                    // path; debug builds cross-check the incremental
                    // artifacts but drop the graph again in delta mode, so
                    // the lazy merge-phase materialization stays covered by
                    // the (debug) test suite.
                    let mut graph = None;
                    if cfg!(debug_assertions) || !cfg.delta_eval {
                        let mut mg = g.apply_delta(view.delta());
                        mg.compact();
                        if cfg!(debug_assertions) {
                            if let Err(e) = mg.validate() {
                                panic!(
                                    "rule {} produced invalid graph: {e:?}",
                                    site.rule_name()
                                );
                            }
                            debug_assert_eq!(
                                h,
                                graph_hash(&mg),
                                "incremental hash diverged for rule {}",
                                site.rule_name()
                            );
                            debug_assert_eq!(mg.len(), view.live_count());
                        }
                        if !cfg.delta_eval {
                            graph = Some(mg);
                        }
                    }
                    cands.push(PendingCand { parent: pi, rule: site.rule_name(), view, graph });
                }
            }
            if cands.is_empty() {
                continue;
            }

            // --- Evaluate the wave (parallel), then merge in sequence
            // order so parallel and sequential runs take identical
            // best/enqueue decisions.
            let outcomes = run_parallel(cands.len(), workers, |i| {
                let c = &cands[i];
                if cfg.delta_eval {
                    let (table, assignment) =
                        entry_cost[c.parent].as_ref().expect("delta mode builds entry bases");
                    let base = DeltaBase {
                        graph: &wave[c.parent].graph,
                        shapes: &entry_shapes[c.parent],
                        table,
                        assignment,
                        converged: Some(&wave[c.parent].assignment),
                    };
                    evaluate_candidate_delta(&base, &c.view, oracle, cf, cfg)
                } else {
                    let g = c.graph.as_ref().expect("full mode materializes up front");
                    evaluate_candidate(g, oracle, cf, cfg)
                }
            });
            // Lazy materialization: a candidate becomes a real graph at
            // most once, and only when it wins or enqueues.
            let materialize = |cached: &mut Option<Graph>, c: &PendingCand<'_>| {
                if cached.is_none() {
                    let mut mg = wave[c.parent].graph.apply_delta(c.view.delta());
                    mg.compact();
                    *cached = Some(mg);
                }
            };
            for (ci, outcome) in outcomes.into_iter().enumerate() {
                let (inner, profiled) = outcome?;
                stats.evaluated += 1;
                stats.profiled += profiled;
                stats.add_inner(&inner);
                let value = cf.eval(&inner.cost);
                let mut cached: Option<Graph> = cands[ci].graph.take();
                if value < best_value {
                    materialize(&mut cached, &cands[ci]);
                    let g = cached.as_ref().expect("materialized above");
                    rule_acc.entry(cands[ci].rule).or_default().2 += best_value - value;
                    best_value = value;
                    best_cost = inner.cost;
                    best_graph = g.clone();
                    best_assignment = inner.assignment.clone();
                    if trajectory.len() < 64 {
                        trajectory.push((g.clone(), inner.assignment.clone(), inner.cost));
                    }
                }
                if value < cfg.alpha * best_value {
                    materialize(&mut cached, &cands[ci]);
                    rule_acc.entry(cands[ci].rule).or_default().1 += 1;
                    seq += 1;
                    queue.push(QueueEntry {
                        value,
                        seq,
                        graph: cached.take().expect("materialized above"),
                        assignment: inner.assignment,
                    });
                }
            }
        }
    }

    stats.rule_stats = rule_acc
        .into_iter()
        .map(|(name, (sites, enqueued, objective_gain))| RuleStat {
            name: name.to_string(),
            sites,
            enqueued,
            objective_gain,
        })
        .collect();
    let argmin1 = oracle.argmin_stats();
    stats.argmin_hits = argmin1.hits - argmin0.hits;
    stats.argmin_misses = argmin1.misses - argmin0.misses;
    stats.wall_s = t_start.elapsed().as_secs_f64();
    Ok(OuterResult {
        graph: best_graph,
        assignment: best_assignment,
        cost: best_cost,
        objective_value: best_value,
        stats,
        trajectory,
    })
}
