//! Outer search (paper Algorithm 1): α-relaxed backtracking over the
//! equivalent-graph space, calling the inner search on every candidate.
//!
//! ```text
//! A0 = innerSearch(G0);  Q = {(G0, A0)};  (Gopt, Aopt) = (G0, A0)
//! while Q != {}:
//!   (G, A) = Q.dequeue()
//!   for G' in Si(G), i in 1..m:
//!     A' = innerSearch(G')
//!     if Cost(G', A') < Cost(Gopt, Aopt): (Gopt, Aopt) = (G', A')
//!     if Cost(G', A') < α * Cost(Gopt, Aopt): Q.enqueue(G', A')
//! return (Gopt, Aopt)
//! ```
//!
//! α=1 degenerates to greedy; larger α explores more of the space at the
//! cost of search time (paper §3.3, following MetaFlow). We add the two
//! standard engineering guards MetaFlow uses: canonical-hash dedup of
//! visited graphs and a budget on dequeued states.
//!
//! ## Batched frontier expansion
//!
//! Candidate evaluation (profile → cost table → inner search) is the whole
//! cost of Algorithm 1, so the loop is organized around **waves**: pop
//! every queue entry currently inside the α-band, generate all their
//! substitution neighbors, dedup by canonical hash, then evaluate the
//! surviving candidates **in parallel** (`SearchConfig::threads` workers
//! over the shared [`CostOracle`]) and merge the results in candidate
//! sequence order. Because evaluation of one candidate is independent of
//! the incumbent, and the merge applies best/enqueue updates in the same
//! deterministic order regardless of which worker finished first, the
//! returned `(graph, assignment, cost)` is **bit-identical across thread
//! counts** whenever the cost provider is deterministic (the default sim
//! provider is; real-wallclock `CpuProvider` measurements are inherently
//! noisy) — `threads: 8` is then purely a wall-clock optimization (see
//! `rust/tests/determinism.rs`).

use super::inner::{inner_search, pinned_freq_start, InnerResult};
use crate::algo::Assignment;
use crate::cost::{CostFunction, CostOracle, GraphCost, GraphCostTable};
use crate::energysim::FreqId;
use crate::graph::canonical::graph_hash;
use crate::graph::Graph;
use crate::subst::RuleSet;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex};

/// How the search treats the DVFS frequency axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DvfsMode {
    /// Nominal clock only — bit-identical to the pre-DVFS search.
    #[default]
    Off,
    /// One frequency state per candidate graph: every state is evaluated
    /// with a full inner search and the best (graph, A, f) wins. Models
    /// application-level `nvidia-smi -lgc` style locking.
    PerGraph,
    /// Frequency is a per-node decision, optimized jointly with the
    /// algorithm by the inner search (kernel-launch granularity DVFS).
    PerNode,
}

impl DvfsMode {
    /// Parse a CLI/config spec (`off`, `per-graph`, `per-node`).
    pub fn parse(spec: &str) -> anyhow::Result<DvfsMode> {
        Ok(match spec {
            "off" => DvfsMode::Off,
            "per-graph" | "per_graph" => DvfsMode::PerGraph,
            "per-node" | "per_node" => DvfsMode::PerNode,
            other => anyhow::bail!("unknown dvfs mode `{other}` (off|per-graph|per-node)"),
        })
    }

    /// Stable display name (inverse of [`DvfsMode::parse`]).
    pub fn describe(&self) -> &'static str {
        match self {
            DvfsMode::Off => "off",
            DvfsMode::PerGraph => "per-graph",
            DvfsMode::PerNode => "per-node",
        }
    }
}

/// Tuning knobs of the optimizer.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Relaxation factor α ≥ 1 (paper uses 1.05 in §4.1).
    pub alpha: f64,
    /// Inner-search neighborhood distance; `None` = the paper's
    /// recommendation (1 for linear objectives, 2 otherwise).
    pub inner_distance: Option<usize>,
    /// Enable the outer (graph substitution) search.
    pub enable_outer: bool,
    /// Enable the inner (algorithm assignment) search.
    pub enable_inner: bool,
    /// Hard cap on dequeued states (defense against α too large).
    pub max_dequeues: usize,
    /// Worker threads for candidate evaluation. `1` = sequential,
    /// `0` = one per available core. With a deterministic cost provider
    /// (the default sim) the optimized plan is bit-identical for every
    /// value; only wall-clock changes.
    pub threads: usize,
    /// DVFS frequency axis: off, one state per graph, or per node.
    pub dvfs: DvfsMode,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            alpha: 1.05,
            inner_distance: None,
            enable_outer: true,
            enable_inner: true,
            max_dequeues: 2_000,
            threads: 1,
            dvfs: DvfsMode::Off,
        }
    }
}

impl SearchConfig {
    /// The worker count `threads` resolves to (0 = available parallelism).
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }
}

/// Search statistics for reporting and ablations.
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    /// Graphs dequeued and expanded.
    pub expanded: usize,
    /// Candidate graphs generated by substitutions.
    pub generated: usize,
    /// Candidates skipped because an isomorphic graph was already seen.
    pub deduped: usize,
    /// Inner-search cost evaluations.
    pub inner_evals: u64,
    /// Rule-name → number of times its product was enqueued.
    pub rules_applied: Vec<(String, usize)>,
    /// Total profile measurements triggered by new signatures.
    pub profiled: usize,
    /// Frontier waves expanded (each wave = one parallel evaluation batch).
    pub waves: usize,
    /// Worker threads used for candidate evaluation.
    pub threads: usize,
    /// Search wallclock, seconds.
    pub wall_s: f64,
}

/// Result of `outer_search`.
pub struct OuterResult {
    /// The best graph found.
    pub graph: Graph,
    /// Its optimized per-node assignment.
    pub assignment: Assignment,
    /// Cost of the best (graph, assignment) pair.
    pub cost: GraphCost,
    /// Objective value of the best pair.
    pub objective_value: f64,
    /// Search statistics.
    pub stats: SearchStats,
    /// Best-so-far trajectory: every (G, A, cost) at which the incumbent
    /// improved, in discovery order (origin first). Capped at 64 entries.
    /// These are the "graphs from the search process" of the paper's
    /// Table 2.
    pub trajectory: Vec<(Graph, Assignment, GraphCost)>,
}

struct QueueEntry {
    value: f64,
    seq: usize, // FIFO tiebreak for equal costs (determinism)
    graph: Graph,
    /// Kept for Algorithm-1 fidelity (the paper enqueues (G, A) pairs);
    /// expansion re-derives A' — including its frequency states — per
    /// candidate, so it is not read here.
    #[allow(dead_code)]
    assignment: Assignment,
}

impl PartialEq for QueueEntry {
    fn eq(&self, other: &Self) -> bool {
        self.value == other.value && self.seq == other.seq
    }
}
impl Eq for QueueEntry {}
impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert to pop the *cheapest* first
        // (MetaFlow's best-first backtracking), break ties FIFO.
        other
            .value
            .partial_cmp(&self.value)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The execution environment of the optimizer: the substitution rule set
/// plus a shared handle to the thread-safe [`CostOracle`] (algorithm
/// registry, profile database, resolve cache, measurement provider).
///
/// The oracle is an `Arc` so one warm cache can back optimize → serve →
/// re-optimize flows without re-profiling; clone the handle freely.
pub struct OptimizerContext {
    /// The substitution rule set defining the equivalent-graph space.
    pub rules: RuleSet,
    /// The shared thread-safe cost-evaluation service.
    pub oracle: Arc<CostOracle>,
}

impl OptimizerContext {
    /// Default context: standard rules + simulated-V100 profiles (seed 7).
    pub fn offline_default() -> OptimizerContext {
        OptimizerContext::new(
            RuleSet::standard(),
            crate::cost::CostDb::new(),
            Box::new(crate::profiler::SimV100Provider::new(7)),
        )
    }

    /// Build a context from rules + profile DB + measurement provider.
    pub fn new(
        rules: RuleSet,
        db: crate::cost::CostDb,
        provider: Box<dyn crate::profiler::CostProvider>,
    ) -> OptimizerContext {
        OptimizerContext {
            rules,
            oracle: Arc::new(CostOracle::new(crate::algo::AlgorithmRegistry::new(), db, provider)),
        }
    }

    /// Build around an existing (possibly already warm) oracle.
    pub fn with_oracle(rules: RuleSet, oracle: Arc<CostOracle>) -> OptimizerContext {
        OptimizerContext { rules, oracle }
    }

    /// The algorithm registry (delegates to the oracle).
    pub fn reg(&self) -> &crate::algo::AlgorithmRegistry {
        self.oracle.reg()
    }

    /// Profile `g` into the database and build its cost table.
    pub fn table_for(&self, g: &Graph) -> anyhow::Result<(GraphCostTable, usize)> {
        self.oracle.table_for(g)
    }
}

/// The origin graph's cost table and default-assignment cost, evaluated
/// once and reused by both `optimize` (objective normalization) and
/// `outer_search` (trajectory origin, inner-search start).
pub struct Baseline {
    /// The origin graph's cost table.
    pub table: GraphCostTable,
    /// The framework-default assignment for the origin graph.
    pub assignment: Assignment,
    /// Origin cost under the default assignment.
    pub cost: GraphCost,
    /// Profile measurements triggered while building the table.
    pub profiled: usize,
}

/// Evaluate the origin graph once (profile + table + default assignment).
pub fn evaluate_baseline(g0: &Graph, oracle: &CostOracle) -> anyhow::Result<Baseline> {
    let shapes = g0.infer_shapes().map_err(|e| anyhow::anyhow!("invalid input graph: {e}"))?;
    let (table, profiled) = oracle.table_for_with(g0, &shapes);
    let assignment = Assignment::default_for_with(g0, &shapes, oracle.reg());
    let cost = table.eval(&assignment);
    Ok(Baseline { table, assignment, cost, profiled })
}

/// Evaluate one candidate graph: validate (shape inference, once), profile
/// missing signatures, inner-search (or default assignment when disabled).
/// With DVFS enabled the frequency axis is optimized here too — per-graph
/// by trying every state, per-node by handing the inner search the joint
/// (algorithm, frequency) option space.
fn evaluate_candidate(
    g: &Graph,
    oracle: &CostOracle,
    cf: &CostFunction,
    cfg: &SearchConfig,
) -> anyhow::Result<(InnerResult, usize)> {
    // Single shape inference per candidate — this IS the validation, and
    // the profile/table/assignment steps below all reuse it (§Perf).
    let shapes = g.infer_shapes().map_err(|e| anyhow::anyhow!("invalid candidate: {e}"))?;
    let freqs = oracle.dvfs_freqs();
    if cfg.dvfs == DvfsMode::Off || freqs.is_empty() {
        let (table, profiled) = oracle.table_for_with(g, &shapes);
        let start = Assignment::default_for_with(g, &shapes, oracle.reg());
        let inner = run_inner(&table, start, cf, cfg);
        return Ok((inner, profiled));
    }
    match cfg.dvfs {
        DvfsMode::PerGraph => {
            // One full inner search per state; NOMINAL goes first so ties
            // resolve to the nominal clock (and the off-mode plan).
            let base = Assignment::default_for_with(g, &shapes, oracle.reg());
            let mut profiled = 0usize;
            let mut extra_evals = 0u64;
            let mut best: Option<(f64, InnerResult)> = None;
            for f in std::iter::once(FreqId::NOMINAL).chain(freqs.iter().copied()) {
                let (table, p) = oracle.table_for_freqs(g, &shapes, &[f]);
                profiled += p;
                let inner = run_inner(&table, pinned_freq_start(&base, f), cf, cfg);
                extra_evals += inner.evals;
                let v = cf.eval(&inner.cost);
                if best.as_ref().is_none_or(|(bv, _)| v < *bv) {
                    best = Some((v, inner));
                }
            }
            let (_, mut inner) = best.expect("at least the nominal state evaluated");
            inner.evals = extra_evals;
            Ok((inner, profiled))
        }
        DvfsMode::PerNode => {
            let mut all = Vec::with_capacity(freqs.len() + 1);
            all.push(FreqId::NOMINAL);
            all.extend_from_slice(freqs);
            let (table, profiled) = oracle.table_for_freqs(g, &shapes, &all);
            let start = Assignment::default_for_with(g, &shapes, oracle.reg());
            let inner = run_inner(&table, start, cf, cfg);
            Ok((inner, profiled))
        }
        DvfsMode::Off => unreachable!("handled above"),
    }
}

fn run_inner(
    table: &GraphCostTable,
    start: Assignment,
    cf: &CostFunction,
    cfg: &SearchConfig,
) -> InnerResult {
    if cfg.enable_inner {
        let d = cfg.inner_distance.unwrap_or_else(|| cf.recommended_inner_distance());
        inner_search(table, cf, d, start)
    } else {
        let cost = table.eval(&start);
        InnerResult { assignment: start, cost, sweeps: 0, evals: 0 }
    }
}

type EvalOutcome = anyhow::Result<(InnerResult, usize)>;

/// The frequency component of the candidate dedup identity: a hash of the
/// search's DVFS mode and frequency domain. Mixing it into the visited-set
/// key means a graph seen under one frequency search space can never be
/// conflated with the same graph under another. It is deliberately NOT
/// per-parent-state: candidate evaluation is frequency-context-free (each
/// candidate re-derives its own best states from scratch), so within one
/// run the component is constant and every graph is evaluated exactly
/// once. In `--dvfs off` the keying is a bijection of the pre-DVFS one,
/// so dedup decisions are bit-for-bit unchanged.
fn freq_domain_hash(cfg: &SearchConfig, oracle: &CostOracle) -> u64 {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;
    let mix = |h: u64, x: u64| (h ^ x).wrapping_mul(FNV_PRIME);
    let mode = match cfg.dvfs {
        DvfsMode::Off => 0u64,
        DvfsMode::PerGraph => 1,
        DvfsMode::PerNode => 2,
    };
    let mut h = mix(0xCBF2_9CE4_8422_2325, mode);
    if cfg.dvfs != DvfsMode::Off {
        for f in oracle.dvfs_freqs() {
            h = mix(h, f.0 as u64);
        }
    }
    h
}

/// Evaluate a wave of candidates, in parallel when `workers > 1`. The
/// returned vector is index-aligned with `cands` regardless of which
/// worker evaluated which candidate.
fn evaluate_wave(
    cands: &[(Graph, &'static str)],
    oracle: &CostOracle,
    cf: &CostFunction,
    cfg: &SearchConfig,
    workers: usize,
) -> Vec<EvalOutcome> {
    if workers <= 1 || cands.len() <= 1 {
        return cands.iter().map(|(g, _)| evaluate_candidate(g, oracle, cf, cfg)).collect();
    }
    let n = cands.len();
    let slots: Vec<Mutex<Option<EvalOutcome>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, AtomicOrdering::Relaxed);
                if i >= n {
                    break;
                }
                let outcome = evaluate_candidate(&cands[i].0, oracle, cf, cfg);
                *slots[i].lock().unwrap() = Some(outcome);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("every wave slot evaluated"))
        .collect()
}

/// Algorithm 1 over batched frontier waves. `cf` must already be
/// normalized if desired; `baseline` is the origin evaluation from
/// [`evaluate_baseline`] (computed once by the caller — see `optimize`).
pub fn outer_search(
    g0: &Graph,
    ctx: &OptimizerContext,
    cf: &CostFunction,
    cfg: &SearchConfig,
    baseline: &Baseline,
) -> anyhow::Result<OuterResult> {
    let t_start = std::time::Instant::now();
    let oracle = &*ctx.oracle;
    let workers = cfg.effective_threads().max(1);
    let mut stats = SearchStats { threads: workers, ..Default::default() };
    let mut rule_counts: std::collections::BTreeMap<String, usize> = Default::default();

    // Inner search on the origin reuses the baseline table: no second
    // profile/table pass for g0. With DVFS enabled the origin gets the
    // full frequency-aware evaluation instead, so the untransformed graph
    // competes on the same (G, A, f) footing as every candidate.
    let inner0 = if cfg.dvfs == DvfsMode::Off || oracle.dvfs_freqs().is_empty() {
        run_inner(&baseline.table, baseline.assignment.clone(), cf, cfg)
    } else {
        let (inner, profiled) = evaluate_candidate(g0, oracle, cf, cfg)?;
        stats.profiled += profiled;
        inner
    };
    stats.inner_evals += inner0.evals;

    let mut best_graph = g0.clone();
    let mut best_assignment = inner0.assignment.clone();
    let mut best_cost = inner0.cost;
    let mut best_value = cf.eval(&best_cost);
    let mut trajectory: Vec<(Graph, Assignment, GraphCost)> = Vec::new();
    // Origin with its default assignment is the first trajectory point.
    trajectory.push((g0.clone(), baseline.assignment.clone(), baseline.cost));
    if inner0.assignment != baseline.assignment {
        trajectory.push((g0.clone(), inner0.assignment.clone(), inner0.cost));
    }

    if cfg.enable_outer && !ctx.rules.is_empty() {
        let freq_domain = freq_domain_hash(cfg, oracle);
        let mut seen: HashSet<u64> = HashSet::new();
        seen.insert(graph_hash(g0) ^ freq_domain);
        let mut queue: BinaryHeap<QueueEntry> = BinaryHeap::new();
        let mut seq = 0usize;
        queue.push(QueueEntry {
            value: cf.eval(&inner0.cost),
            seq,
            graph: g0.clone(),
            assignment: inner0.assignment,
        });

        loop {
            // --- Pop one wave: every queued entry inside the α-band, up
            // to the remaining dequeue budget. The band test uses the
            // incumbent as of wave start, so wave composition does not
            // depend on evaluation order (or thread count).
            let mut wave: Vec<QueueEntry> = Vec::new();
            while stats.expanded < cfg.max_dequeues {
                let Some(entry) = queue.pop() else { break };
                // Backtracking prune: entries enqueued before `best`
                // improved may now fall outside the α-band — drop on pop.
                if entry.value >= cfg.alpha * best_value && entry.value > best_value {
                    continue;
                }
                stats.expanded += 1;
                wave.push(entry);
            }
            if wave.is_empty() {
                break;
            }
            stats.waves += 1;

            // --- Generate all substitution neighbors, dedup by canonical
            // hash + frequency domain (sequential: order defines candidate
            // sequence numbers).
            let mut cands: Vec<(Graph, &'static str)> = Vec::new();
            for entry in &wave {
                for (cand, rule_name) in ctx.rules.neighbors(&entry.graph) {
                    stats.generated += 1;
                    if !seen.insert(graph_hash(&cand) ^ freq_domain) {
                        stats.deduped += 1;
                        continue;
                    }
                    cands.push((cand, rule_name));
                }
            }
            if cands.is_empty() {
                continue;
            }

            // --- Evaluate the wave (parallel), then merge in sequence
            // order so parallel and sequential runs take identical
            // best/enqueue decisions.
            let outcomes = evaluate_wave(&cands, oracle, cf, cfg, workers);
            for ((cand, rule_name), outcome) in cands.into_iter().zip(outcomes) {
                let (inner, profiled) = outcome?;
                stats.profiled += profiled;
                stats.inner_evals += inner.evals;
                let value = cf.eval(&inner.cost);
                if value < best_value {
                    best_value = value;
                    best_cost = inner.cost;
                    best_graph = cand.clone();
                    best_assignment = inner.assignment.clone();
                    if trajectory.len() < 64 {
                        trajectory.push((cand.clone(), inner.assignment.clone(), inner.cost));
                    }
                }
                if value < cfg.alpha * best_value {
                    *rule_counts.entry(rule_name.to_string()).or_default() += 1;
                    seq += 1;
                    queue.push(QueueEntry {
                        value,
                        seq,
                        graph: cand,
                        assignment: inner.assignment,
                    });
                }
            }
        }
    }

    stats.rules_applied = rule_counts.into_iter().collect();
    stats.wall_s = t_start.elapsed().as_secs_f64();
    Ok(OuterResult {
        graph: best_graph,
        assignment: best_assignment,
        cost: best_cost,
        objective_value: best_value,
        stats,
        trajectory,
    })
}
