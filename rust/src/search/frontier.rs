//! Pareto **plan frontiers**: instead of one plan per objective, enumerate
//! the set of mutually non-dominated `(graph, assignment, frequency)` plans
//! over the (latency, energy) plane.
//!
//! The paper frames the user choice as "optimize energy consumption *or
//! balance* between energy and inference performance" — but the trade-off
//! is a genuine frontier, not a point (the GPU-DVFS study of
//! arXiv:1905.11012 maps it empirically, and PolyThrottle shows the best
//! operating point shifts with load). This module exposes that frontier:
//!
//! - [`optimize_frontier`] sweeps the energy/performance weight of the
//!   linear objective across `n` probes, reusing the α-band wave machinery
//!   of [`outer_search`] per probe (the shared [`CostOracle`] makes repeat
//!   probes nearly profile-free), and harvests every probe's best-so-far
//!   trajectory as frontier candidates.
//! - [`optimize_frontier_batched`] adds the third axis: the same weight
//!   sweep repeated per batch size over [`Graph::rebatch`]'d instances of
//!   the origin, so the frontier becomes a surface of **(plan, freq,
//!   batch) operating points**. Batch rides through node signatures (input
//!   shapes carry the batch dim), so the cost stack — energysim work,
//!   `CostDb` rows, resolve cache, slabs, delta carry-over — keys on batch
//!   with no special cases, and `batches = [1]` reproduces
//!   [`optimize_frontier`] bit for bit.
//! - [`PlanFrontier`] holds the dominance-pruned result, ordered by batch
//!   latency with strictly decreasing **energy per request**
//!   (`energy_j / batch`) — no point dominates another, by construction.
//!
//! Downstream, `runtime::manifest` persists frontiers to versioned JSON
//! (v3 when any point carries `batch > 1`) and `serve::FrontierController`
//! moves across the frontier at serve time as the live request rate moves
//! (`eadgo serve --frontier plans.json --adaptive`).
//!
//! [`CostOracle`]: crate::cost::CostOracle

use super::outer::{evaluate_baseline, outer_search, OptimizerContext, SearchConfig};
use crate::algo::Assignment;
use crate::cost::{CostFunction, CostOracle, GraphCost};
use crate::energysim::FreqId;
use crate::graph::canonical::graph_hash;
use crate::graph::Graph;
use std::cmp::Ordering;

/// One operating point on a Pareto frontier: a full `(graph, assignment)`
/// pair (the assignment carries any DVFS states) plus the batch size the
/// plan was costed at, its estimated cost and the objective weight of the
/// probe that discovered it.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    /// The optimized computation graph (instantiated at `batch`).
    pub graph: Graph,
    /// The per-node algorithm (and DVFS state) assignment.
    pub assignment: Assignment,
    /// The cost oracle's estimate for this plan — the **full-batch** cost:
    /// `time_ms` is the batch latency, `energy_j` the energy of one batch
    /// (mJ per batch execution).
    pub cost: GraphCost,
    /// Weight on energy (`w` of `w·E/E₀ + (1-w)·T/T₀`) of the probe that
    /// produced the point: 0 = pure time, 1 = pure energy.
    pub weight: f64,
    /// Batch size this operating point was searched and costed at.
    /// Pre-batch-axis plans are `batch = 1` (their amortized values equal
    /// the raw cost exactly: IEEE division by 1.0 is the identity).
    pub batch: usize,
}

impl PlanPoint {
    /// Energy per request, mJ — `energy_j / batch`, the quantity the
    /// frontier trades against batch latency.
    pub fn energy_per_request(&self) -> f64 {
        self.cost.energy_j / self.batch as f64
    }

    /// Amortized per-request service time, ms — `time_ms / batch`, the
    /// reciprocal of this operating point's throughput capacity.
    pub fn time_per_request_ms(&self) -> f64 {
        self.cost.time_ms / self.batch as f64
    }

    /// Pareto dominance over (batch latency, energy per request): `self`
    /// dominates `other` when it is no worse on both axes and strictly
    /// better on at least one. At `batch = 1` on both sides this is the
    /// pre-batch-axis (latency, energy) dominance, bit for bit.
    pub fn dominates(&self, other: &PlanPoint) -> bool {
        let (se, oe) = (self.energy_per_request(), other.energy_per_request());
        self.cost.time_ms <= other.cost.time_ms
            && se <= oe
            && (self.cost.time_ms < other.cost.time_ms || se < oe)
    }
}

/// A dominance-pruned Pareto set of operating points, sorted fastest-first
/// by batch latency: strictly increasing `time_ms`, strictly decreasing
/// energy per request (`energy_j / batch`). Index 0 is the latency-optimal
/// point, the last index the (per-request) energy-optimal point. For the
/// all-`batch = 1` frontiers of the pre-batch-axis pipeline the amortized
/// ordering coincides with the raw (time, energy) ordering exactly.
#[derive(Debug, Clone, Default)]
pub struct PlanFrontier {
    points: Vec<PlanPoint>,
}

impl PlanFrontier {
    /// Build a frontier from arbitrary candidate points: dominated points
    /// (and exact duplicates of an earlier point's cost) are dropped, the
    /// survivors sorted fastest-first. Deterministic: ties keep the
    /// earliest candidate.
    pub fn from_points(mut points: Vec<PlanPoint>) -> PlanFrontier {
        points.sort_by(|a, b| {
            a.cost
                .time_ms
                .partial_cmp(&b.cost.time_ms)
                .unwrap_or(Ordering::Equal)
                .then(
                    a.energy_per_request()
                        .partial_cmp(&b.energy_per_request())
                        .unwrap_or(Ordering::Equal),
                )
        });
        // After the (time asc, energy/request asc) stable sort, a point is
        // on the frontier iff its per-request energy is strictly below
        // every kept predecessor — checking the last kept suffices because
        // kept energies are strictly decreasing.
        let mut kept: Vec<PlanPoint> = Vec::new();
        for p in points {
            if kept
                .last()
                .is_some_and(|k| p.energy_per_request() >= k.energy_per_request())
            {
                continue;
            }
            kept.push(p);
        }
        PlanFrontier { points: kept }
    }

    /// The frontier's plans, fastest-first.
    pub fn points(&self) -> &[PlanPoint] {
        &self.points
    }

    /// Number of plans on the frontier.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Whether the frontier holds no plans.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The fastest plan (lowest `time_ms`). Panics on an empty frontier.
    pub fn latency_optimal(&self) -> &PlanPoint {
        self.points.first().expect("empty frontier")
    }

    /// The cheapest operating point (lowest energy per request). Panics on
    /// an empty frontier.
    pub fn energy_optimal(&self) -> &PlanPoint {
        self.points.last().expect("empty frontier")
    }

    /// The estimated cost of every plan, frontier order.
    pub fn costs(&self) -> Vec<GraphCost> {
        self.points.iter().map(|p| p.cost).collect()
    }

    /// Thin the frontier to at most `n` points, always keeping both
    /// extremes and sampling evenly in between (deterministic).
    pub fn thin_to(&mut self, n: usize) {
        if n == 0 || self.points.len() <= n {
            return;
        }
        if n == 1 {
            // Degenerate request: keep the energy-optimal extreme.
            self.points = vec![self.points.pop().expect("non-empty")];
            return;
        }
        let len = self.points.len();
        let mut out = Vec::with_capacity(n);
        for (i, p) in std::mem::take(&mut self.points).into_iter().enumerate() {
            let wanted = (0..n).any(|k| k * (len - 1) / (n - 1) == i);
            if wanted {
                out.push(p);
            }
        }
        self.points = out;
    }
}

/// One weight probe of a frontier enumeration (for reporting/ablation).
#[derive(Debug, Clone, Copy)]
pub struct FrontierProbe {
    /// Weight on energy of the probe objective.
    pub weight: f64,
    /// Cost of the probe's winning plan (full-batch cost at `batch`).
    pub cost: GraphCost,
    /// Search wallclock of the probe, seconds.
    pub wall_s: f64,
    /// Batch size the probe searched at.
    pub batch: usize,
}

/// Outcome of [`optimize_frontier`].
pub struct FrontierResult {
    /// The dominance-pruned Pareto set (at most `n` plans).
    pub frontier: PlanFrontier,
    /// Cost of the origin graph under the default assignment.
    pub original: GraphCost,
    /// Per-probe trace, in probe order.
    pub probes: Vec<FrontierProbe>,
}

/// Enumerate an (at most) `n`-point Pareto frontier over (latency, energy)
/// for `g0`.
///
/// Sweeps the energy weight `w` of the linear objective over `n` evenly
/// spaced probes from 0 (pure time) to 1 (pure energy); every probe runs
/// the full two-level α-band search ([`outer_search`]) against the shared
/// cost oracle, so signatures profile once across the whole sweep. Each
/// probe contributes its winning plan *and* its best-so-far trajectory as
/// candidates; the dominance prune then keeps the non-dominated set,
/// thinned to `n` evenly spaced points when richer.
///
/// `n == 1` is exactly today's single-plan energy optimization: the result
/// is bit-identical to `optimize(g0, ctx, &CostFunction::Energy, cfg)`
/// (property-tested in `rust/tests/frontier.rs`).
///
/// Every probe inherits the outer search's delta candidate evaluation
/// (`SearchConfig::delta_eval`): probes 2..N re-walk largely overlapping
/// graph neighborhoods, so carry-over cost tables and incremental hashing
/// compound across the sweep. The frontier is engine-invariant — every
/// point byte-identical between the delta and legacy full-rebuild paths
/// (`rust/tests/determinism.rs`).
pub fn optimize_frontier(
    g0: &Graph,
    ctx: &OptimizerContext,
    cfg: &SearchConfig,
    n: usize,
) -> anyhow::Result<FrontierResult> {
    optimize_frontier_batched(g0, ctx, cfg, n, &[1])
}

/// Enumerate a joint **(plan, freq, batch)** operating-point frontier: the
/// full `n`-probe weight sweep of [`optimize_frontier`] repeated at every
/// batch size in `batches`, over [`Graph::rebatch`]'d instances of `g0`.
///
/// Each batch sweeps against the *same* shared [`CostOracle`]: rebatched
/// graphs present batch-specific node signatures, so their profiles land
/// in distinct `CostDb` rows and resolve-cache entries without colliding
/// with (or invalidating) the batch-1 state — repeat sweeps stay warm per
/// batch. Candidates from all batches are dominance-pruned together under
/// the (batch latency, energy per request) order and thinned to at most
/// `n * batches.len()` points.
///
/// `batches = [1]` skips rebatching entirely (the batch-1 sweep runs on
/// `g0` itself) and is bit-identical to [`optimize_frontier`] — which is
/// literally this function with `batches = [1]`. `original` is the origin
/// graph's default-plan cost at `batches[0]`.
///
/// `batches` must be non-empty, strictly increasing, and start at >= 1.
///
/// [`CostOracle`]: crate::cost::CostOracle
pub fn optimize_frontier_batched(
    g0: &Graph,
    ctx: &OptimizerContext,
    cfg: &SearchConfig,
    n: usize,
    batches: &[usize],
) -> anyhow::Result<FrontierResult> {
    optimize_frontier_batched_warm(g0, ctx, cfg, n, batches, None)
}

/// [`optimize_frontier_batched`] with an optional **warm-start hint**: an
/// assignment for `g0` (typically the currently-served plan of a previous
/// search over the same origin) seeded into the first probe's baseline as
/// [`Baseline::warm_hint`]. For the additive probe objectives the sweep
/// uses, warm starts are result-neutral by construction — the frontier is
/// bit-identical with or without the hint; the hint only attributes the
/// first origin inner search as warm. The dominant re-search saving comes
/// from the shared [`CostOracle`] instead: a re-search against an oracle
/// warmed by a previous sweep resolves (and measures) almost nothing.
///
/// A hint whose length does not match `g0` is ignored (the caller may be
/// holding a plan for a *rewritten* graph; such a plan cannot seed the
/// origin's inner search).
///
/// This is the feedback loop's re-optimization entry point: on sustained
/// drift, `serve::ServeSession` re-runs the sweep here against the
/// feedback-corrected oracle, warm-started from the live surface.
///
/// [`Baseline::warm_hint`]: super::outer::Baseline::warm_hint
/// [`CostOracle`]: crate::cost::CostOracle
pub fn optimize_frontier_batched_warm(
    g0: &Graph,
    ctx: &OptimizerContext,
    cfg: &SearchConfig,
    n: usize,
    batches: &[usize],
    warm: Option<&Assignment>,
) -> anyhow::Result<FrontierResult> {
    anyhow::ensure!(n >= 1, "frontier size must be >= 1");
    anyhow::ensure!(!batches.is_empty(), "batch sweep must name at least one batch size");
    anyhow::ensure!(batches[0] >= 1, "batch sizes must be >= 1");
    anyhow::ensure!(
        batches.windows(2).all(|w| w[0] < w[1]),
        "batch sizes must be strictly increasing"
    );
    g0.validate().map_err(|e| anyhow::anyhow!("invalid input graph: {e}"))?;

    let mut candidates: Vec<PlanPoint> = Vec::new();
    let mut probes: Vec<FrontierProbe> = Vec::with_capacity(n * batches.len());
    let mut original: Option<GraphCost> = None;
    // The hint only fits the origin graph itself (node ids must line up),
    // so it seeds the first swept batch's first probe and nothing else.
    let mut warm = warm.filter(|a| a.len() == g0.len()).cloned();
    for &batch in batches {
        let gb;
        let g = if batch == 1 {
            g0 // no clone, no rebatch: the batch-1 sweep is the legacy path
        } else {
            gb = g0.rebatch(batch).map_err(|e| anyhow::anyhow!("rebatch({batch}): {e}"))?;
            &gb
        };
        let o = sweep_weights(g, ctx, cfg, n, batch, warm.take(), &mut candidates, &mut probes)?;
        original.get_or_insert(o);
    }
    let mut frontier = PlanFrontier::from_points(candidates);
    frontier.thin_to(n * batches.len());
    Ok(FrontierResult {
        frontier,
        original: original.expect("at least one batch swept"),
        probes,
    })
}

/// One `n`-probe weight sweep over `g` (already instantiated at `batch`),
/// appending candidates and probe traces; returns the origin cost. `warm`
/// seeds the first probe's origin inner search (see
/// [`optimize_frontier_batched_warm`]); later probes chain off the
/// previous probe's origin plan as before.
#[allow(clippy::too_many_arguments)]
fn sweep_weights(
    g: &Graph,
    ctx: &OptimizerContext,
    cfg: &SearchConfig,
    n: usize,
    batch: usize,
    warm: Option<Assignment>,
    candidates: &mut Vec<PlanPoint>,
    probes: &mut Vec<FrontierProbe>,
) -> anyhow::Result<GraphCost> {
    if n == 1 {
        let res = super::optimize(g, ctx, &CostFunction::Energy, cfg)?;
        probes.push(FrontierProbe {
            weight: 1.0,
            cost: res.cost,
            wall_s: res.stats.wall_s,
            batch,
        });
        candidates.push(PlanPoint {
            graph: res.graph,
            assignment: res.assignment,
            cost: res.cost,
            weight: 1.0,
            batch,
        });
        return Ok(res.original);
    }

    let h0 = graph_hash(g);
    let mut original: Option<GraphCost> = None;
    // Probes 2..N warm-start their origin inner search from the previous
    // probe's origin plan (the adjacent weight's converged assignment).
    // For the linear probe objective the separable search is
    // start-independent, so this is result-neutral by construction — it
    // attributes the origin runs as warm in the economy counters and
    // seeds the basin for any future non-additive probe objective. The
    // caller's warm hint plays the same role for probe 1.
    let mut prev_origin: Option<Assignment> = warm;
    for i in 0..n {
        let w = i as f64 / (n - 1) as f64;
        // Same pipeline as `optimize`: evaluate the baseline once per
        // probe (fully cached after the first), normalize, search.
        let mut baseline = evaluate_baseline(g, &ctx.oracle)?;
        baseline.warm_hint = prev_origin.take();
        let cf = CostFunction::linear(w).normalized(&baseline.cost);
        let res = outer_search(g, ctx, &cf, cfg, &baseline)?;
        original.get_or_insert(baseline.cost);
        // The probe's origin plan: only the first two trajectory entries
        // can be g0 (entry 0 is the default plan, entry 1 — when present
        // — the origin's converged inner search; later entries are
        // deduped candidates, never g0), so at most two hashes here.
        prev_origin = res
            .trajectory
            .iter()
            .take(2)
            .rev()
            .find(|(tg, _, _)| graph_hash(tg) == h0)
            .map(|(_, a, _)| a.clone());
        probes.push(FrontierProbe { weight: w, cost: res.cost, wall_s: res.stats.wall_s, batch });
        // Harvest the probe's whole improvement trajectory — intermediate
        // plans a pure-w probe walked through are often non-dominated
        // points of their own.
        for (tg, a, c) in res.trajectory {
            candidates.push(PlanPoint { graph: tg, assignment: a, cost: c, weight: w, batch });
        }
        candidates.push(PlanPoint {
            graph: res.graph,
            assignment: res.assignment,
            cost: res.cost,
            weight: w,
            batch,
        });
    }
    Ok(original.expect("at least one probe ran"))
}

/// Price an existing plan at a different batch size: rebatch the plan's
/// graph, build a cost table over exactly the DVFS states the assignment
/// references, and evaluate. Node ids survive [`Graph::rebatch`]
/// unchanged and algorithm applicability is batch-invariant (it depends on
/// kernel geometry and strides, never on the leading activation dim), so
/// the original assignment remains valid verbatim.
///
/// This is how the serve layer builds its per-(plan, m) cost grid: a batch
/// formed below the operating point's target is charged the oracle's
/// estimate for the batch it actually ran, not the target's amortized
/// ideal. `batch = 1` reproduces the plan's stored cost bit for bit (same
/// signatures, same cached rows).
pub fn price_plan_at_batch(
    oracle: &CostOracle,
    g: &Graph,
    a: &Assignment,
    batch: usize,
) -> anyhow::Result<GraphCost> {
    let gb = g.rebatch(batch).map_err(|e| anyhow::anyhow!("rebatch({batch}): {e}"))?;
    let shapes = gb
        .infer_shapes()
        .map_err(|e| anyhow::anyhow!("shape inference at batch {batch}: {e}"))?;
    // The table needs exactly the DVFS states the assignment references
    // (NOMINAL always, for the baseline slab).
    let mut freqs = vec![FreqId::NOMINAL];
    for id in gb.ids() {
        let f = a.freq(id);
        if !freqs.contains(&f) {
            freqs.push(f);
        }
    }
    let (table, _) = oracle.table_for_freqs(&gb, &shapes, &freqs);
    Ok(table.eval(a))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energysim::FreqId;

    fn point(time_ms: f64, energy_j: f64) -> PlanPoint {
        point_at(time_ms, energy_j, 1)
    }

    fn point_at(time_ms: f64, energy_j: f64, batch: usize) -> PlanPoint {
        let reg = crate::algo::AlgorithmRegistry::new();
        PlanPoint {
            graph: Graph::new(),
            assignment: Assignment::default_for(&Graph::new(), &reg),
            cost: GraphCost { time_ms, energy_j, freq: FreqId::NOMINAL },
            weight: 0.5,
            batch,
        }
    }

    #[test]
    fn pruning_keeps_only_nondominated() {
        let f = PlanFrontier::from_points(vec![
            point(2.0, 50.0),
            point(1.0, 100.0),
            point(1.5, 120.0), // dominated by (1.0, 100)
            point(3.0, 40.0),
            point(2.5, 60.0), // dominated by (2.0, 50)
        ]);
        let costs: Vec<(f64, f64)> =
            f.points().iter().map(|p| (p.cost.time_ms, p.cost.energy_j)).collect();
        assert_eq!(costs, vec![(1.0, 100.0), (2.0, 50.0), (3.0, 40.0)]);
        for (i, a) in f.points().iter().enumerate() {
            for (j, b) in f.points().iter().enumerate() {
                if i != j {
                    assert!(!a.dominates(b), "point {i} dominates {j}");
                }
            }
        }
        assert_eq!(f.latency_optimal().cost.time_ms, 1.0);
        assert_eq!(f.energy_optimal().cost.energy_j, 40.0);
    }

    #[test]
    fn duplicate_costs_collapse() {
        let f = PlanFrontier::from_points(vec![point(1.0, 10.0), point(1.0, 10.0)]);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn equal_time_keeps_lower_energy() {
        let f = PlanFrontier::from_points(vec![point(1.0, 20.0), point(1.0, 10.0)]);
        assert_eq!(f.len(), 1);
        assert_eq!(f.points()[0].cost.energy_j, 10.0);
    }

    #[test]
    fn thinning_keeps_extremes() {
        let mut f = PlanFrontier::from_points(
            (0..10).map(|i| point(1.0 + i as f64, 100.0 - 5.0 * i as f64)).collect(),
        );
        f.thin_to(4);
        assert_eq!(f.len(), 4);
        assert_eq!(f.latency_optimal().cost.time_ms, 1.0);
        assert_eq!(f.energy_optimal().cost.time_ms, 10.0);
        // still sorted and dominance-free
        for w in f.points().windows(2) {
            assert!(w[0].cost.time_ms < w[1].cost.time_ms);
            assert!(w[0].cost.energy_j > w[1].cost.energy_j);
        }
    }

    #[test]
    fn thin_to_one_keeps_energy_optimal() {
        let mut f = PlanFrontier::from_points(vec![point(1.0, 100.0), point(2.0, 50.0)]);
        f.thin_to(1);
        assert_eq!(f.len(), 1);
        assert_eq!(f.points()[0].cost.energy_j, 50.0);
    }

    #[test]
    fn empty_frontier_is_fine() {
        let f = PlanFrontier::from_points(Vec::new());
        assert!(f.is_empty());
        assert_eq!(f.len(), 0);
    }

    #[test]
    fn dominance_compares_energy_per_request() {
        // A batch-8 point with 4x the batch energy of a batch-1 point is
        // 2x cheaper per request: if it is also no slower, it dominates.
        let slow_single = point_at(2.0, 100.0, 1); // 100 mJ/request
        let batched = point_at(2.0, 400.0, 8); // 50 mJ/request
        assert!(batched.dominates(&slow_single));
        assert!(!slow_single.dominates(&batched));
        // A faster batch-1 point survives against a cheaper batch-8 one:
        // neither dominates (lat vs energy/request trade).
        let fast_single = point_at(1.0, 120.0, 1);
        assert!(!batched.dominates(&fast_single));
        assert!(!fast_single.dominates(&batched));
    }

    #[test]
    fn pruning_orders_mixed_batches_by_amortized_energy() {
        let f = PlanFrontier::from_points(vec![
            point_at(1.0, 120.0, 1),  // 120 mJ/request, fastest
            point_at(4.0, 400.0, 8),  // 50 mJ/request
            point_at(2.0, 100.0, 1),  // 100 mJ/request
            point_at(3.0, 880.0, 8),  // 110 mJ/request — dominated by (2.0, 100/req)
        ]);
        let kept: Vec<(f64, usize)> =
            f.points().iter().map(|p| (p.energy_per_request(), p.batch)).collect();
        assert_eq!(kept, vec![(120.0, 1), (100.0, 1), (50.0, 8)]);
        assert_eq!(f.energy_optimal().batch, 8);
        assert_eq!(f.latency_optimal().batch, 1);
    }

    #[test]
    fn per_request_helpers_are_identity_at_batch_one() {
        let p = point_at(1.5, 42.0, 1);
        // IEEE: x / 1.0 == x exactly — the batch axis is invisible at 1.
        assert_eq!(p.energy_per_request().to_bits(), p.cost.energy_j.to_bits());
        assert_eq!(p.time_per_request_ms().to_bits(), p.cost.time_ms.to_bits());
        let q = point_at(3.0, 42.0, 4);
        assert_eq!(q.energy_per_request(), 10.5);
        assert_eq!(q.time_per_request_ms(), 0.75);
    }
}
