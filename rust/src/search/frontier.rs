//! Pareto **plan frontiers**: instead of one plan per objective, enumerate
//! the set of mutually non-dominated `(graph, assignment, frequency)` plans
//! over the (latency, energy) plane.
//!
//! The paper frames the user choice as "optimize energy consumption *or
//! balance* between energy and inference performance" — but the trade-off
//! is a genuine frontier, not a point (the GPU-DVFS study of
//! arXiv:1905.11012 maps it empirically, and PolyThrottle shows the best
//! operating point shifts with load). This module exposes that frontier:
//!
//! - [`optimize_frontier`] sweeps the energy/performance weight of the
//!   linear objective across `n` probes, reusing the α-band wave machinery
//!   of [`outer_search`] per probe (the shared [`CostOracle`] makes repeat
//!   probes nearly profile-free), and harvests every probe's best-so-far
//!   trajectory as frontier candidates.
//! - [`PlanFrontier`] holds the dominance-pruned result: plans sorted
//!   fastest-first, with strictly increasing time and strictly decreasing
//!   energy — no point dominates another, by construction.
//!
//! Downstream, `runtime::manifest` persists frontiers to versioned JSON and
//! `serve::FrontierController` switches the active plan across the frontier
//! at serve time as the live request rate moves (`eadgo serve --frontier
//! plans.json --adaptive`).
//!
//! [`CostOracle`]: crate::cost::CostOracle

use super::outer::{evaluate_baseline, outer_search, OptimizerContext, SearchConfig};
use crate::algo::Assignment;
use crate::cost::{CostFunction, GraphCost};
use crate::graph::canonical::graph_hash;
use crate::graph::Graph;
use std::cmp::Ordering;

/// One plan on a Pareto frontier: a full `(graph, assignment)` pair (the
/// assignment carries any DVFS states) plus its estimated cost and the
/// objective weight of the probe that discovered it.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    /// The optimized computation graph.
    pub graph: Graph,
    /// The per-node algorithm (and DVFS state) assignment.
    pub assignment: Assignment,
    /// The cost oracle's estimate for this plan.
    pub cost: GraphCost,
    /// Weight on energy (`w` of `w·E/E₀ + (1-w)·T/T₀`) of the probe that
    /// produced the point: 0 = pure time, 1 = pure energy.
    pub weight: f64,
}

impl PlanPoint {
    /// Pareto dominance over (latency, energy): `self` dominates `other`
    /// when it is no worse on both axes and strictly better on at least
    /// one.
    pub fn dominates(&self, other: &PlanPoint) -> bool {
        self.cost.time_ms <= other.cost.time_ms
            && self.cost.energy_j <= other.cost.energy_j
            && (self.cost.time_ms < other.cost.time_ms
                || self.cost.energy_j < other.cost.energy_j)
    }
}

/// A dominance-pruned Pareto set of plans, sorted fastest-first: strictly
/// increasing `time_ms`, strictly decreasing `energy_j`. Index 0 is the
/// latency-optimal plan, the last index the energy-optimal plan.
#[derive(Debug, Clone, Default)]
pub struct PlanFrontier {
    points: Vec<PlanPoint>,
}

impl PlanFrontier {
    /// Build a frontier from arbitrary candidate points: dominated points
    /// (and exact duplicates of an earlier point's cost) are dropped, the
    /// survivors sorted fastest-first. Deterministic: ties keep the
    /// earliest candidate.
    pub fn from_points(mut points: Vec<PlanPoint>) -> PlanFrontier {
        points.sort_by(|a, b| {
            a.cost
                .time_ms
                .partial_cmp(&b.cost.time_ms)
                .unwrap_or(Ordering::Equal)
                .then(
                    a.cost
                        .energy_j
                        .partial_cmp(&b.cost.energy_j)
                        .unwrap_or(Ordering::Equal),
                )
        });
        // After the (time asc, energy asc) stable sort, a point is on the
        // frontier iff its energy is strictly below every kept predecessor
        // — checking the last kept suffices because kept energies are
        // strictly decreasing.
        let mut kept: Vec<PlanPoint> = Vec::new();
        for p in points {
            if kept.last().is_some_and(|k| p.cost.energy_j >= k.cost.energy_j) {
                continue;
            }
            kept.push(p);
        }
        PlanFrontier { points: kept }
    }

    /// The frontier's plans, fastest-first.
    pub fn points(&self) -> &[PlanPoint] {
        &self.points
    }

    /// Number of plans on the frontier.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Whether the frontier holds no plans.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The fastest plan (lowest `time_ms`). Panics on an empty frontier.
    pub fn latency_optimal(&self) -> &PlanPoint {
        self.points.first().expect("empty frontier")
    }

    /// The cheapest plan (lowest `energy_j`). Panics on an empty frontier.
    pub fn energy_optimal(&self) -> &PlanPoint {
        self.points.last().expect("empty frontier")
    }

    /// The estimated cost of every plan, frontier order.
    pub fn costs(&self) -> Vec<GraphCost> {
        self.points.iter().map(|p| p.cost).collect()
    }

    /// Thin the frontier to at most `n` points, always keeping both
    /// extremes and sampling evenly in between (deterministic).
    pub fn thin_to(&mut self, n: usize) {
        if n == 0 || self.points.len() <= n {
            return;
        }
        if n == 1 {
            // Degenerate request: keep the energy-optimal extreme.
            self.points = vec![self.points.pop().expect("non-empty")];
            return;
        }
        let len = self.points.len();
        let mut out = Vec::with_capacity(n);
        for (i, p) in std::mem::take(&mut self.points).into_iter().enumerate() {
            let wanted = (0..n).any(|k| k * (len - 1) / (n - 1) == i);
            if wanted {
                out.push(p);
            }
        }
        self.points = out;
    }
}

/// One weight probe of a frontier enumeration (for reporting/ablation).
#[derive(Debug, Clone, Copy)]
pub struct FrontierProbe {
    /// Weight on energy of the probe objective.
    pub weight: f64,
    /// Cost of the probe's winning plan.
    pub cost: GraphCost,
    /// Search wallclock of the probe, seconds.
    pub wall_s: f64,
}

/// Outcome of [`optimize_frontier`].
pub struct FrontierResult {
    /// The dominance-pruned Pareto set (at most `n` plans).
    pub frontier: PlanFrontier,
    /// Cost of the origin graph under the default assignment.
    pub original: GraphCost,
    /// Per-probe trace, in probe order.
    pub probes: Vec<FrontierProbe>,
}

/// Enumerate an (at most) `n`-point Pareto frontier over (latency, energy)
/// for `g0`.
///
/// Sweeps the energy weight `w` of the linear objective over `n` evenly
/// spaced probes from 0 (pure time) to 1 (pure energy); every probe runs
/// the full two-level α-band search ([`outer_search`]) against the shared
/// cost oracle, so signatures profile once across the whole sweep. Each
/// probe contributes its winning plan *and* its best-so-far trajectory as
/// candidates; the dominance prune then keeps the non-dominated set,
/// thinned to `n` evenly spaced points when richer.
///
/// `n == 1` is exactly today's single-plan energy optimization: the result
/// is bit-identical to `optimize(g0, ctx, &CostFunction::Energy, cfg)`
/// (property-tested in `rust/tests/frontier.rs`).
///
/// Every probe inherits the outer search's delta candidate evaluation
/// (`SearchConfig::delta_eval`): probes 2..N re-walk largely overlapping
/// graph neighborhoods, so carry-over cost tables and incremental hashing
/// compound across the sweep. The frontier is engine-invariant — every
/// point byte-identical between the delta and legacy full-rebuild paths
/// (`rust/tests/determinism.rs`).
pub fn optimize_frontier(
    g0: &Graph,
    ctx: &OptimizerContext,
    cfg: &SearchConfig,
    n: usize,
) -> anyhow::Result<FrontierResult> {
    anyhow::ensure!(n >= 1, "frontier size must be >= 1");
    g0.validate().map_err(|e| anyhow::anyhow!("invalid input graph: {e}"))?;
    if n == 1 {
        let res = super::optimize(g0, ctx, &CostFunction::Energy, cfg)?;
        let point = PlanPoint {
            graph: res.graph,
            assignment: res.assignment,
            cost: res.cost,
            weight: 1.0,
        };
        return Ok(FrontierResult {
            frontier: PlanFrontier::from_points(vec![point]),
            original: res.original,
            probes: vec![FrontierProbe {
                weight: 1.0,
                cost: res.cost,
                wall_s: res.stats.wall_s,
            }],
        });
    }

    let h0 = graph_hash(g0);
    let mut candidates: Vec<PlanPoint> = Vec::new();
    let mut probes: Vec<FrontierProbe> = Vec::with_capacity(n);
    let mut original: Option<GraphCost> = None;
    // Probes 2..N warm-start their origin inner search from the previous
    // probe's origin plan (the adjacent weight's converged assignment).
    // For the linear probe objective the separable search is
    // start-independent, so this is result-neutral by construction — it
    // attributes the origin runs as warm in the economy counters and
    // seeds the basin for any future non-additive probe objective.
    let mut prev_origin: Option<Assignment> = None;
    for i in 0..n {
        let w = i as f64 / (n - 1) as f64;
        // Same pipeline as `optimize`: evaluate the baseline once per
        // probe (fully cached after the first), normalize, search.
        let mut baseline = evaluate_baseline(g0, &ctx.oracle)?;
        baseline.warm_hint = prev_origin.take();
        let cf = CostFunction::linear(w).normalized(&baseline.cost);
        let res = outer_search(g0, ctx, &cf, cfg, &baseline)?;
        original.get_or_insert(baseline.cost);
        // The probe's origin plan: only the first two trajectory entries
        // can be g0 (entry 0 is the default plan, entry 1 — when present
        // — the origin's converged inner search; later entries are
        // deduped candidates, never g0), so at most two hashes here.
        prev_origin = res
            .trajectory
            .iter()
            .take(2)
            .rev()
            .find(|(g, _, _)| graph_hash(g) == h0)
            .map(|(_, a, _)| a.clone());
        probes.push(FrontierProbe { weight: w, cost: res.cost, wall_s: res.stats.wall_s });
        // Harvest the probe's whole improvement trajectory — intermediate
        // plans a pure-w probe walked through are often non-dominated
        // points of their own.
        for (g, a, c) in res.trajectory {
            candidates.push(PlanPoint { graph: g, assignment: a, cost: c, weight: w });
        }
        candidates.push(PlanPoint {
            graph: res.graph,
            assignment: res.assignment,
            cost: res.cost,
            weight: w,
        });
    }
    let mut frontier = PlanFrontier::from_points(candidates);
    frontier.thin_to(n);
    Ok(FrontierResult {
        frontier,
        original: original.expect("at least one probe ran"),
        probes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energysim::FreqId;

    fn point(time_ms: f64, energy_j: f64) -> PlanPoint {
        let reg = crate::algo::AlgorithmRegistry::new();
        PlanPoint {
            graph: Graph::new(),
            assignment: Assignment::default_for(&Graph::new(), &reg),
            cost: GraphCost { time_ms, energy_j, freq: FreqId::NOMINAL },
            weight: 0.5,
        }
    }

    #[test]
    fn pruning_keeps_only_nondominated() {
        let f = PlanFrontier::from_points(vec![
            point(2.0, 50.0),
            point(1.0, 100.0),
            point(1.5, 120.0), // dominated by (1.0, 100)
            point(3.0, 40.0),
            point(2.5, 60.0), // dominated by (2.0, 50)
        ]);
        let costs: Vec<(f64, f64)> =
            f.points().iter().map(|p| (p.cost.time_ms, p.cost.energy_j)).collect();
        assert_eq!(costs, vec![(1.0, 100.0), (2.0, 50.0), (3.0, 40.0)]);
        for (i, a) in f.points().iter().enumerate() {
            for (j, b) in f.points().iter().enumerate() {
                if i != j {
                    assert!(!a.dominates(b), "point {i} dominates {j}");
                }
            }
        }
        assert_eq!(f.latency_optimal().cost.time_ms, 1.0);
        assert_eq!(f.energy_optimal().cost.energy_j, 40.0);
    }

    #[test]
    fn duplicate_costs_collapse() {
        let f = PlanFrontier::from_points(vec![point(1.0, 10.0), point(1.0, 10.0)]);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn equal_time_keeps_lower_energy() {
        let f = PlanFrontier::from_points(vec![point(1.0, 20.0), point(1.0, 10.0)]);
        assert_eq!(f.len(), 1);
        assert_eq!(f.points()[0].cost.energy_j, 10.0);
    }

    #[test]
    fn thinning_keeps_extremes() {
        let mut f = PlanFrontier::from_points(
            (0..10).map(|i| point(1.0 + i as f64, 100.0 - 5.0 * i as f64)).collect(),
        );
        f.thin_to(4);
        assert_eq!(f.len(), 4);
        assert_eq!(f.latency_optimal().cost.time_ms, 1.0);
        assert_eq!(f.energy_optimal().cost.time_ms, 10.0);
        // still sorted and dominance-free
        for w in f.points().windows(2) {
            assert!(w[0].cost.time_ms < w[1].cost.time_ms);
            assert!(w[0].cost.energy_j > w[1].cost.energy_j);
        }
    }

    #[test]
    fn thin_to_one_keeps_energy_optimal() {
        let mut f = PlanFrontier::from_points(vec![point(1.0, 100.0), point(2.0, 50.0)]);
        f.thin_to(1);
        assert_eq!(f.len(), 1);
        assert_eq!(f.points()[0].cost.energy_j, 50.0);
    }

    #[test]
    fn empty_frontier_is_fine() {
        let f = PlanFrontier::from_points(Vec::new());
        assert!(f.is_empty());
        assert_eq!(f.len(), 0);
    }
}
