//! The two-level energy-aware search (paper §3.3) and the public
//! `optimize` entry point.

/// Constrained optimization (binary search on the linear weight, §4.4).
pub mod constrained;
/// Pareto plan-frontier enumeration over (latency, energy).
pub mod frontier;
/// Inner search: algorithm assignment of a fixed graph (Algorithm 2).
pub mod inner;
/// Outer search: α-relaxed backtracking over equivalent graphs (Algorithm 1).
pub mod outer;

pub use constrained::{
    optimize_with_time_budget, refine_frequency_to_budget, refine_states_to_budget,
    synthesize_contingency, ConstrainedResult,
};
pub use frontier::{
    optimize_frontier, optimize_frontier_batched, optimize_frontier_batched_warm,
    price_plan_at_batch, FrontierProbe, FrontierResult, PlanFrontier, PlanPoint,
};
pub use inner::{
    exhaustive_search, inner_search, inner_search_incremental, random_assignment, InnerResult,
};
pub use outer::{
    evaluate_baseline, outer_search, Baseline, DvfsMode, OptimizerContext, OuterResult,
    RuleStat, SearchConfig, SearchStats,
};

use crate::algo::Assignment;
use crate::cost::{CostFunction, GraphCost};
use crate::graph::Graph;

/// Outcome of a full optimization run, with the origin baseline attached
/// for savings reporting.
pub struct OptimizeResult {
    /// The optimized computation graph.
    pub graph: Graph,
    /// The optimized per-node algorithm (and DVFS state) assignment.
    pub assignment: Assignment,
    /// Cost of the optimized (G, A) under the additive model.
    pub cost: GraphCost,
    /// Cost of the origin graph under the default assignment.
    pub original: GraphCost,
    /// Objective value of the optimized plan.
    pub objective_value: f64,
    /// Objective value of the origin plan.
    pub original_objective: f64,
    /// Normalized objective actually used (after baseline normalization).
    pub objective: CostFunction,
    /// Search statistics (expansions, waves, profiles, wallclock).
    pub stats: SearchStats,
}

impl OptimizeResult {
    /// Fractional savings on the objective (0.24 = 24% better).
    pub fn objective_savings(&self) -> f64 {
        if self.original_objective > 0.0 {
            1.0 - self.objective_value / self.original_objective
        } else {
            0.0
        }
    }

    /// Fractional energy savings versus the origin plan.
    pub fn energy_savings(&self) -> f64 {
        1.0 - self.cost.energy_j / self.original.energy_j.max(1e-12)
    }

    /// Fractional inference-time savings versus the origin plan.
    pub fn time_savings(&self) -> f64 {
        1.0 - self.cost.time_ms / self.original.time_ms.max(1e-12)
    }
}

/// Optimize `g0` for `objective`: profiles as needed, normalizes the
/// objective against the origin cost, then runs the two-level search.
///
/// The origin graph is profiled and evaluated exactly once (the
/// [`Baseline`]); both the objective normalization here and the search's
/// trajectory origin reuse it.
pub fn optimize(
    g0: &Graph,
    ctx: &OptimizerContext,
    objective: &CostFunction,
    cfg: &SearchConfig,
) -> anyhow::Result<OptimizeResult> {
    g0.validate().map_err(|e| anyhow::anyhow!("invalid input graph: {e}"))?;
    // Baseline: origin graph, default assignment — evaluated once.
    let baseline = evaluate_baseline(g0, &ctx.oracle)?;
    let original = baseline.cost;
    let cf = objective.normalized(&original);
    let original_objective = cf.eval(&original);

    let mut result = outer_search(g0, ctx, &cf, cfg, &baseline)?;
    result.stats.profiled += baseline.profiled;
    Ok(OptimizeResult {
        graph: result.graph,
        assignment: result.assignment,
        cost: result.cost,
        original,
        objective_value: result.objective_value,
        original_objective,
        objective: cf,
        stats: result.stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, OpKind, PortRef};

    /// Two parallel convs + concat + relu: rich enough for both levels.
    fn test_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.add1(OpKind::Input { shape: vec![1, 16, 64, 64] }, &[], "x");
        let w1 = g.add1(OpKind::weight(vec![16, 16, 3, 3], 1), &[], "w1");
        let w2 = g.add1(OpKind::weight(vec![16, 16, 3, 3], 2), &[], "w2");
        let c1 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::None,
                has_bias: false,
                has_residual: false,
            },
            &[x, w1],
            "c1",
        );
        let c2 = g.add1(
            OpKind::Conv2d {
                stride: (1, 1),
                pad: (1, 1),
                act: Activation::None,
                has_bias: false,
                has_residual: false,
            },
            &[x, w2],
            "c2",
        );
        let cat = g.add1(OpKind::Concat { axis: 1 }, &[c1, c2], "cat");
        let r = g.add1(OpKind::Relu, &[cat], "relu");
        g.outputs = vec![PortRef::of(r)];
        g
    }

    #[test]
    fn optimize_energy_beats_origin() {
        let g = test_graph();
        let ctx = OptimizerContext::offline_default();
        let res = optimize(&g, &ctx, &CostFunction::Energy, &SearchConfig::default()).unwrap();
        assert!(
            res.cost.energy_j < res.original.energy_j,
            "optimized {} vs origin {}",
            res.cost.energy_j,
            res.original.energy_j
        );
    }

    #[test]
    fn optimize_time_beats_origin() {
        let g = test_graph();
        let ctx = OptimizerContext::offline_default();
        let res = optimize(&g, &ctx, &CostFunction::Time, &SearchConfig::default()).unwrap();
        assert!(res.cost.time_ms <= res.original.time_ms);
        assert!(res.objective_savings() >= 0.0);
    }

    #[test]
    fn inner_only_vs_both_ablation() {
        let g = test_graph();
        let ctx = OptimizerContext::offline_default();
        let both = optimize(&g, &ctx, &CostFunction::Energy, &SearchConfig::default()).unwrap();
        let ctx2 = OptimizerContext::offline_default();
        let inner_only = optimize(
            &g,
            &ctx2,
            &CostFunction::Energy,
            &SearchConfig { enable_outer: false, ..Default::default() },
        )
        .unwrap();
        // Both-levels can never be worse than inner alone (it includes it).
        assert!(both.cost.energy_j <= inner_only.cost.energy_j + 1e-9);
    }

    #[test]
    fn disabled_everything_is_origin() {
        let g = test_graph();
        let ctx = OptimizerContext::offline_default();
        let res = optimize(
            &g,
            &ctx,
            &CostFunction::Energy,
            &SearchConfig { enable_outer: false, enable_inner: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(res.cost, res.original);
        assert!((res.objective_savings()).abs() < 1e-12);
    }

    #[test]
    fn alpha_one_is_greedy_and_terminates() {
        let g = test_graph();
        let ctx = OptimizerContext::offline_default();
        let res = optimize(
            &g,
            &ctx,
            &CostFunction::Energy,
            &SearchConfig { alpha: 1.0, ..Default::default() },
        )
        .unwrap();
        assert!(res.cost.energy_j <= res.original.energy_j);
    }

    #[test]
    fn power_objective_trades_time() {
        let g = test_graph();
        let ctx = OptimizerContext::offline_default();
        let res = optimize(&g, &ctx, &CostFunction::Power, &SearchConfig::default()).unwrap();
        // minimum power should not exceed origin power
        assert!(res.cost.power_w() <= res.original.power_w() + 1e-9);
    }
}
