//! Pareto plan frontiers end-to-end: enumerate, persist, reload, and serve
//! adaptively under two load regimes.
//!
//!   1. Enumerate an N-point (latency, energy) frontier for SqueezeNet by
//!      sweeping the energy weight through the two-level search.
//!   2. Persist it as a versioned frontier manifest and reload it (the
//!      `optimize --save-frontier` / `serve --frontier` round-trip).
//!   3. Serve the frontier through the reference engine with the
//!      load-adaptive `FrontierController`: under light traffic it parks
//!      on the energy-optimal plan; under heavy traffic it escalates to
//!      the latency-optimal plan, and the report logs every switch.
//!
//! Run: `cargo run --release --example pareto_serve [-- --points 4 --requests 96]`

use eadgo::engine::ReferenceEngine;
use eadgo::models::{self, ModelConfig};
use eadgo::report::f3;
use eadgo::report::tables::frontier_table;
use eadgo::search::{optimize_frontier, OptimizerContext, SearchConfig};
use eadgo::serve::{AdaptiveConfig, ServeConfig, ServeSession, ServiceModel};
use eadgo::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let args = eadgo::util::cli::Args::from_env(false);
    args.require_known(&["points", "requests"])?;
    let n = args.get_usize("points", 4)?;
    let requests = args.get_usize("requests", 96)?;

    // --- 1. enumerate the frontier ----------------------------------------
    let mcfg = ModelConfig { batch: 1, resolution: 64, width_div: 2, classes: 10 };
    let g = models::squeezenet::build(mcfg);
    let ctx = OptimizerContext::offline_default();
    let scfg = SearchConfig { max_dequeues: 60, ..Default::default() };
    println!("[1/3] enumerating a {n}-point pareto frontier (squeezenet, sim-V100)...");
    let res = optimize_frontier(&g, &ctx, &scfg, n)?;
    print!("{}", frontier_table(&res.frontier, Some(&res.original)).render());

    // --- 2. persist + reload ----------------------------------------------
    let dir = std::env::temp_dir().join("eadgo_pareto_serve");
    let path = dir.join("plans.json");
    eadgo::runtime::manifest::save_frontier(&path, &res.frontier)?;
    let reg = eadgo::algo::AlgorithmRegistry::new();
    let frontier = eadgo::runtime::manifest::load_frontier(&path, &reg)?;
    println!("[2/3] frontier manifest round-trip: {} plans via {}", frontier.len(), path.display());

    // --- 3. adaptive serving under light vs heavy load ---------------------
    let engine = ReferenceEngine::new();
    let points = frontier.points();
    let plans = points
        .iter()
        .map(|p| engine.plan(&p.graph, &p.assignment))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let costs = frontier.costs();
    let mut exec = |idx: usize, batch: &[Tensor]| -> anyhow::Result<Vec<Tensor>> {
        let p = &points[idx];
        let mut outs = Vec::with_capacity(batch.len());
        for x in batch {
            let o = engine.run_plan(&p.graph, &p.assignment, &plans[idx], std::slice::from_ref(x))?;
            outs.push(
                o.outputs
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("engine returned no outputs"))?,
            );
        }
        Ok(outs)
    };

    println!("[3/3] serving {requests} requests per load regime...\n");
    for (label, rate) in [("light load, 50 req/s", 50.0), ("heavy load, 20k req/s", 20_000.0)] {
        let serve_cfg = ServeConfig {
            requests,
            batch_max: 4,
            arrival_rate_hz: rate,
            max_wait_s: 0.002,
            seed: 2026,
            input_shape: vec![1, 3, 64, 64],
            phases: Vec::new(),
            service: ServiceModel::Wallclock,
        };
        let report = ServeSession::new(&serve_cfg)
            .frontier_costs(&costs)
            .adaptive(AdaptiveConfig::default())
            .run(&mut exec)?;
        let lat = report.latency_summary();
        println!("== {label} ==");
        println!(
            "   p50 {} ms  p99 {} ms   {} switch(es)   plans {}",
            f3(lat.p50 * 1e3),
            f3(lat.p99 * 1e3),
            report.switches.len(),
            report.plan_distribution()
        );
        if let Some(e) = report.energy_mj_per_request {
            println!("   oracle-estimated energy/request: {} mJ", f3(e));
        }
        for s in &report.switches {
            println!(
                "   switch t={:.4}s  p{} -> p{}  (queue {}, rate {:.0} req/s)",
                s.at_s, s.from, s.to, s.queue_depth, s.rate_hz
            );
        }
        println!();
    }
    std::fs::remove_dir_all(&dir).ok();
    println!("pareto_serve OK: frontier enumerated, persisted, served adaptively");
    Ok(())
}
