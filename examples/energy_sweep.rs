//! Energy/time tradeoff sweep (the paper's Table 4 scenario, §4.4):
//! sweep the linear weight w from pure-time to pure-energy and print the
//! frontier, demonstrating "users are able to balance inference time and
//! energy at their preference".
//!
//! Run: `cargo run --release --example energy_sweep [-- --model resnet]`

use eadgo::cost::CostFunction;
use eadgo::models::{self, ModelConfig};
use eadgo::report::{f3, Table};
use eadgo::search::{optimize, OptimizerContext, SearchConfig};

fn main() -> anyhow::Result<()> {
    let args = eadgo::util::cli::Args::from_env(false);
    let model = args.get_or("model", "squeezenet").to_string();
    let cfg = ModelConfig { batch: 1, resolution: 224, width_div: 1, classes: 1000 };
    let graph = models::by_name(&model, cfg)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let scfg = SearchConfig { max_dequeues: 120, ..Default::default() };

    let mut t = Table::new(
        &format!("energy/time frontier — {model} (sim-V100)"),
        &["w(energy)", "time_ms", "power_w", "energy_j/1k", "Δtime vs fastest", "Δenergy vs thriftiest"],
    );
    let mut rows = Vec::new();
    for we in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let ctx = OptimizerContext::offline_default();
        let res = optimize(&graph, &ctx, &CostFunction::linear(we), &scfg)?;
        rows.push((we, res.cost));
        eprintln!("  w={we:.1} done ({} graphs expanded)", res.stats.expanded);
    }
    let t_min = rows.iter().map(|(_, c)| c.time_ms).fold(f64::INFINITY, f64::min);
    let e_min = rows.iter().map(|(_, c)| c.energy_j).fold(f64::INFINITY, f64::min);
    for (we, c) in &rows {
        t.row(vec![
            format!("{we:.1}"),
            f3(c.time_ms),
            f3(c.power_w()),
            f3(c.energy_j),
            format!("{:+.1}%", 100.0 * (c.time_ms / t_min - 1.0)),
            format!("{:+.1}%", 100.0 * (c.energy_j / e_min - 1.0)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
