//! Constrained optimization (paper §4.4): "less energy as possible, while
//! inference time is faster than T" via binary search on the linear weight
//! — needing only pair-wise cost-model accuracy.
//!
//! Run: `cargo run --release --example constrained_opt`

use eadgo::cost::CostFunction;
use eadgo::models::{self, ModelConfig};
use eadgo::report::f3;
use eadgo::search::{optimize, optimize_with_time_budget, OptimizerContext, SearchConfig};

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig { batch: 1, resolution: 224, width_div: 1, classes: 1000 };
    let graph = models::squeezenet::build(cfg);
    let scfg = SearchConfig { max_dequeues: 120, ..Default::default() };

    // Establish the two endpoints first (paper: "from Table 4 we know the
    // lower bound of inference time ... and energy").
    let ctx = OptimizerContext::offline_default();
    let fastest = optimize(&graph, &ctx, &CostFunction::Time, &scfg)?;
    let thriftiest = optimize(&graph, &ctx, &CostFunction::Energy, &scfg)?;
    println!(
        "endpoints: fastest {} ms / {} J; thriftiest {} ms / {} J",
        f3(fastest.cost.time_ms),
        f3(fastest.cost.energy_j),
        f3(thriftiest.cost.time_ms),
        f3(thriftiest.cost.energy_j)
    );

    // Budget halfway between the endpoints.
    let budget = 0.5 * (fastest.cost.time_ms + thriftiest.cost.time_ms);
    println!("\nconstraint: minimize energy s.t. time <= {} ms", f3(budget));
    let r = optimize_with_time_budget(&graph, &ctx, budget, &scfg, 8)?;
    assert!(r.feasible);
    println!(
        "solution at w={:.4}: time {} ms (budget {}), energy {} J/1k",
        r.weight,
        f3(r.result.cost.time_ms),
        f3(budget),
        f3(r.result.cost.energy_j)
    );
    println!("\nbinary-search trace:");
    println!("  {:>8}  {:>10}  {:>12}", "w", "time_ms", "energy_j/1k");
    for (w, t, e) in &r.trace {
        let ok = if *t <= budget { "feasible" } else { "over budget" };
        println!("  {w:>8.4}  {:>10}  {:>12}  {ok}", f3(*t), f3(*e));
    }

    // An infeasible budget degrades gracefully to the best-time solution.
    let impossible = fastest.cost.time_ms * 0.5;
    let r2 = optimize_with_time_budget(&graph, &ctx, impossible, &scfg, 4)?;
    println!(
        "\ninfeasible budget {} ms -> feasible={} (falls back to best-time: {} ms)",
        f3(impossible),
        r2.feasible,
        f3(r2.result.cost.time_ms)
    );
    Ok(())
}
