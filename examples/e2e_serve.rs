//! End-to-end driver: proves all three layers compose on a real workload.
//!
//!   1. Build the quickstart CNN graph (L3 graph IR).
//!   2. Profile it with *real measured wallclock* on this host (CpuProvider
//!      — the paper's profiling step with a real device, not the sim).
//!   3. Run the two-level energy-aware search on those real profiles.
//!   4. Load the AOT JAX/Pallas artifacts (L1/L2, built by `make
//!      artifacts`) into the PJRT runtime and serve a batch of inference
//!      requests through the hybrid engine under BOTH the default and the
//!      optimized algorithm assignment, verifying outputs agree and
//!      reporting latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serve`

use eadgo::algo::Assignment;
use eadgo::cost::CostFunction;
use eadgo::engine::pjrt::PjrtEngine;
use eadgo::engine::ReferenceEngine;
use eadgo::models::{self, ModelConfig};
use eadgo::profiler::CpuProvider;
use eadgo::report::f3;
use eadgo::runtime::Runtime;
use eadgo::search::{optimize, OptimizerContext, SearchConfig};
use eadgo::tensor::Tensor;
use eadgo::util::rng::Rng;
use eadgo::util::stats::Summary;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = eadgo::util::cli::Args::from_env(false);
    let requests = args.get_usize("requests", 32)?;
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));

    // --- L3: graph + real-measurement profiling + search ------------------
    let cfg = ModelConfig { batch: 1, resolution: 32, width_div: 4, classes: 10 };
    let graph = models::simple::build_cnn(cfg);
    println!(
        "[1/4] graph: quickstart CNN, {} nodes ({} runtime)",
        graph.len(),
        graph.runtime_node_count()
    );

    let ctx = OptimizerContext::new(
        eadgo::subst::RuleSet::standard(),
        eadgo::cost::CostDb::new(),
        Box::new(CpuProvider::new(None)),
    );
    println!("[2/4] profiling every (node, algorithm) pair with real wallclock...");
    let res = optimize(
        &graph,
        &ctx,
        &CostFunction::Energy,
        &SearchConfig { max_dequeues: 30, ..Default::default() },
    )?;
    println!(
        "      optimizer: energy {} -> {} mJ-model-units ({:+.1}%), {} profiles measured",
        f3(res.original.energy_j),
        f3(res.cost.energy_j),
        -100.0 * res.energy_savings(),
        res.stats.profiled
    );

    // --- L1/L2: AOT artifacts through PJRT --------------------------------
    if !Path::new(&artifacts).join("manifest.json").exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let mut rt = Runtime::cpu()?;
    let n = rt.load_dir(&artifacts)?;
    println!("[3/4] PJRT runtime: {} artifacts compiled on `{}`", n, rt.platform());

    // --- serve -------------------------------------------------------------
    let engine = PjrtEngine::new(&rt);
    let reference = ReferenceEngine::new();
    let default_a = Assignment::default_for(&graph, ctx.reg());
    let mut rng = Rng::seed_from(2026);

    let mut run_batch = |label: &str, g: &eadgo::graph::Graph, a: &Assignment| -> anyhow::Result<Summary> {
        // Plan once (constant folding + artifact-key resolution), serve many
        // times — the §Perf serving-path optimization.
        let prepared = engine.prepare(g, a)?;
        let mut lat = Vec::with_capacity(requests);
        let mut check_done = false;
        for _ in 0..requests {
            let x = Tensor::rand(&[1, 3, 32, 32], &mut rng, -1.0, 1.0);
            let t0 = std::time::Instant::now();
            let (out, stats) = engine.run_prepared(g, a, &prepared, std::slice::from_ref(&x))?;
            lat.push(t0.elapsed().as_secs_f64());
            if !check_done {
                // verify against the pure-rust reference once per config
                let want = reference.run(g, a, std::slice::from_ref(&x))?.outputs.remove(0);
                eadgo::util::prop::assert_close(want.data(), out.outputs[0].data(), 1e-3, 1e-3)
                    .map_err(|e| anyhow::anyhow!("hybrid/reference mismatch: {e}"))?;
                println!(
                    "      {label}: outputs verified vs reference ({} pjrt / {} fallback nodes)",
                    stats.pjrt_nodes, stats.reference_nodes
                );
                check_done = true;
            }
        }
        Ok(Summary::of(&lat))
    };

    println!("[4/4] serving {requests} requests per configuration...");
    let s_default = run_batch("default-assignment", &graph, &default_a)?;
    let s_opt = run_batch("optimized", &res.graph, &res.assignment)?;

    println!("\n== serving report (batch=1, quickstart CNN, PJRT-hybrid engine) ==");
    for (label, s) in [("default", &s_default), ("optimized", &s_opt)] {
        println!(
            "{label:<10} p50 {:>8} ms   p95 {:>8} ms   mean {:>8} ms   throughput {:>7.1} req/s",
            f3(s.p50 * 1e3),
            f3(s.p95 * 1e3),
            f3(s.mean * 1e3),
            1.0 / s.mean
        );
    }
    println!("\ne2e OK: L3 search (real profiles) + L2/L1 AOT Pallas artifacts + PJRT serving");
    Ok(())
}
