//! Quickstart: optimize SqueezeNet for energy and print the savings —
//! the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use eadgo::cost::CostFunction;
use eadgo::models::{self, ModelConfig};
use eadgo::report::f3;
use eadgo::search::{optimize, OptimizerContext, SearchConfig};

fn main() -> anyhow::Result<()> {
    // 1. A computation graph (nodes = operators, edges = tensors).
    let cfg = ModelConfig { batch: 1, resolution: 224, width_div: 1, classes: 1000 };
    let graph = models::squeezenet::build(cfg);
    println!("SqueezeNet: {} nodes ({} runtime)", graph.len(), graph.runtime_node_count());

    // 2. An optimizer context: substitution rules + a shared thread-safe
    //    cost oracle (algorithm registry, cost database, simulated-V100
    //    measurement provider).
    let ctx = OptimizerContext::offline_default();

    // 3. Pick an objective (paper §3.2) and search (paper §3.3). With
    //    threads: 0 the outer search evaluates candidates on one worker
    //    per core; the plan is bit-identical to a sequential run.
    let objective = CostFunction::Energy;
    let scfg = SearchConfig { threads: 0, ..Default::default() };
    let result = optimize(&graph, &ctx, &objective, &scfg)?;

    println!("\n              time(ms)  power(W)  energy(J/1k inf)");
    println!(
        "origin        {:>8}  {:>8}  {:>8}",
        f3(result.original.time_ms),
        f3(result.original.power_w()),
        f3(result.original.energy_j)
    );
    println!(
        "optimized     {:>8}  {:>8}  {:>8}",
        f3(result.cost.time_ms),
        f3(result.cost.power_w()),
        f3(result.cost.energy_j)
    );
    println!(
        "\nenergy saved: {:.1}%  (time {:+.1}%)",
        100.0 * result.energy_savings(),
        -100.0 * result.time_savings()
    );
    println!(
        "search: expanded {} graphs in {} waves ({} threads), generated {}, deduped {}, {:.2}s",
        result.stats.expanded,
        result.stats.waves,
        result.stats.threads,
        result.stats.generated,
        result.stats.deduped,
        result.stats.wall_s
    );

    // 4. The optimized graph + assignment are ready for the engine:
    let changed = result
        .assignment
        .assigned_ids()
        .filter(|id| {
            result.graph.node(*id).op.mnemonic() == "conv2d"
        })
        .count();
    println!("optimized graph has {changed} convolutions with tuned algorithm assignments");
    Ok(())
}
