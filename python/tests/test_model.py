"""L2 correctness: the quickstart CNN forward (Pallas-composed) vs the
pure-oracle composition, for every conv algorithm variant."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

RNG = np.random.default_rng(99)


def weights():
    return [jnp.asarray(RNG.standard_normal(s) * 0.2, dtype=jnp.float32) for (_, s) in model.WEIGHT_SPECS]


def test_weight_specs_shapes_consistent():
    ws = weights()
    assert len(ws) == 9
    assert ws[0].shape == (8, 3, 3, 3)
    assert ws[-1].shape == (16, 10)


@pytest.mark.parametrize("algo", ["im2col", "direct", "winograd"])
def test_forward_matches_ref(algo):
    ws = weights()
    x = jnp.asarray(RNG.standard_normal((1, 3, 16, 16)), dtype=jnp.float32)
    got = model.forward(x, *ws, algo=algo)
    want = model.forward_ref(x, *ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_forward_is_distribution():
    ws = weights()
    x = jnp.asarray(RNG.standard_normal((2, 3, 16, 16)), dtype=jnp.float32)
    y = np.asarray(model.forward_ref(x, *ws))
    assert y.shape == (2, 10)
    np.testing.assert_allclose(y.sum(axis=-1), 1.0, rtol=1e-5)
    assert (y >= 0).all()


def test_algorithms_agree_with_each_other():
    ws = weights()
    x = jnp.asarray(RNG.standard_normal((1, 3, 16, 16)), dtype=jnp.float32)
    outs = [np.asarray(model.forward(x, *ws, algo=a)) for a in ["im2col", "direct", "winograd"]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-3, atol=1e-4)
