"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, strides, and padding — the CORE correctness
signal for the kernels that back the paper's per-node "algorithms".
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pallas_conv import (
    conv_direct,
    conv_im2col,
    conv_winograd,
    dwconv_direct,
    im2col,
)
from compile.kernels.pallas_matmul import matmul as pallas_matmul

RNG = np.random.default_rng(1234)


def t(*shape):
    return jnp.asarray(RNG.standard_normal(shape), dtype=jnp.float32)


def close(a, b, tol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
)
def test_matmul_matches_ref(m, k, n):
    a, b = t(m, k), t(k, n)
    close(pallas_matmul(a, b), ref.matmul_ref(a, b))


def test_matmul_tile_boundary_cases():
    # shapes exactly at, below, and above the tile edge
    for m, k, n in [(128, 128, 128), (127, 129, 1), (130, 1, 257)]:
        a, b = t(m, k), t(k, n)
        close(pallas_matmul(a, b, tile_m=128, tile_n=128, tile_k=128), ref.matmul_ref(a, b))


def test_matmul_small_tiles():
    a, b = t(17, 23), t(23, 9)
    close(pallas_matmul(a, b, tile_m=8, tile_n=8, tile_k=8), ref.matmul_ref(a, b))


# ---------------------------------------------------------------------------
# convolutions
# ---------------------------------------------------------------------------

conv_shapes = st.tuples(
    st.integers(1, 2),   # N
    st.integers(1, 4),   # C
    st.integers(5, 10),  # H
    st.integers(5, 10),  # W
    st.integers(1, 4),   # K
)


@settings(max_examples=20, deadline=None)
@given(
    dims=conv_shapes,
    r=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([(1, 1), (2, 2), (1, 2)]),
    padded=st.booleans(),
    bias=st.booleans(),
)
def test_conv_direct_matches_ref(dims, r, stride, padded, bias):
    n, c, h, w, k = dims
    pad = (r // 2, r // 2) if padded else (0, 0)
    x, wt = t(n, c, h, w), t(k, c, r, r)
    b = t(k) if bias else None
    got = conv_direct(x, wt, bias=b, stride=stride, pad=pad)
    want = ref.conv2d_ref(x, wt, bias=b, stride=stride, pad=pad)
    close(got, want)


@settings(max_examples=20, deadline=None)
@given(
    dims=conv_shapes,
    r=st.sampled_from([1, 3]),
    stride=st.sampled_from([(1, 1), (2, 2)]),
    padded=st.booleans(),
)
def test_conv_im2col_matches_ref(dims, r, stride, padded):
    n, c, h, w, k = dims
    pad = (r // 2, r // 2) if padded else (0, 0)
    x, wt = t(n, c, h, w), t(k, c, r, r)
    got = conv_im2col(x, wt, stride=stride, pad=pad)
    want = ref.conv2d_ref(x, wt, stride=stride, pad=pad)
    close(got, want)


@settings(max_examples=20, deadline=None)
@given(dims=conv_shapes, padded=st.booleans(), bias=st.booleans())
def test_conv_winograd_matches_ref(dims, padded, bias):
    n, c, h, w, k = dims
    pad = (1, 1) if padded else (0, 0)
    x, wt = t(n, c, h, w), t(k, c, 3, 3)
    b = t(k) if bias else None
    got = conv_winograd(x, wt, bias=b, pad=pad)
    want = ref.conv2d_ref(x, wt, bias=b, stride=(1, 1), pad=pad)
    close(got, want, tol=5e-4)


def test_im2col_matches_ref_layout():
    x = t(2, 3, 6, 7)
    got = im2col(x, 3, 3, (1, 1), (1, 1))
    want = ref.im2col_ref(x, 3, 3, (1, 1), (1, 1))
    close(got, want)


def test_conv_epilogues():
    """bias + residual + relu fused epilogue matches the oracle."""
    x, wt = t(1, 3, 6, 6), t(4, 3, 3, 3)
    b = t(4)
    res = t(1, 4, 6, 6)
    for fn in (conv_direct, conv_im2col):
        got = fn(x, wt, bias=b, stride=(1, 1), pad=(1, 1), residual=res, relu=True)
        want = ref.conv2d_ref(x, wt, bias=b, stride=(1, 1), pad=(1, 1), residual=res, relu=True)
        close(got, want)


def test_winograd_rejects_non_3x3():
    x, wt = t(1, 1, 6, 6), t(1, 1, 5, 5)
    with pytest.raises(AssertionError):
        conv_winograd(x, wt)


def test_asymmetric_kernels_direct():
    """1x7 / 7x1 factorized convs (Inception-B) through the direct kernel."""
    x = t(1, 3, 9, 9)
    for (r, s, pad) in [(1, 7, (0, 3)), (7, 1, (3, 0))]:
        wt = t(2, 3, r, s)
        got = conv_direct(x, wt, stride=(1, 1), pad=pad)
        want = ref.conv2d_ref(x, wt, stride=(1, 1), pad=pad)
        close(got, want)


# ---------------------------------------------------------------------------
# oracles' self-consistency
# ---------------------------------------------------------------------------


def test_im2col_ref_equals_conv():
    x, wt = t(2, 3, 8, 8), t(4, 3, 3, 3)
    close(
        ref.conv2d_im2col_ref(x, wt, stride=(1, 1), pad=(1, 1)),
        ref.conv2d_ref(x, wt, stride=(1, 1), pad=(1, 1)),
    )


def test_avgpool_excludes_padding():
    x = jnp.ones((1, 1, 4, 4), dtype=jnp.float32) * 2.0
    y = ref.avgpool_ref(x, (3, 3), (1, 1), (1, 1))
    np.testing.assert_allclose(np.asarray(y), 2.0, rtol=1e-6)


def test_softmax_rows_sum_to_one():
    x = t(3, 7)
    s = np.asarray(ref.softmax_ref(x)).sum(axis=-1)
    np.testing.assert_allclose(s, 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# depthwise convolution
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 2),
    c=st.integers(1, 5),
    h=st.integers(5, 10),
    stride=st.sampled_from([(1, 1), (2, 2)]),
    padded=st.booleans(),
    bias=st.booleans(),
)
def test_dwconv_direct_matches_ref(n, c, h, stride, padded, bias):
    pad = (1, 1) if padded else (0, 0)
    x, wt = t(n, c, h, h), t(c, 1, 3, 3)
    b = t(c) if bias else None
    got = dwconv_direct(x, wt, bias=b, stride=stride, pad=pad)
    want = ref.dwconv2d_ref(x, wt, bias=b, stride=stride, pad=pad)
    close(got, want)


def test_dwconv_relu_epilogue():
    x, wt = t(1, 4, 6, 6), t(4, 1, 3, 3)
    got = dwconv_direct(x, wt, stride=(1, 1), pad=(1, 1), relu=True)
    want = ref.dwconv2d_ref(x, wt, stride=(1, 1), pad=(1, 1), relu=True)
    close(got, want)
