"""AOT pipeline tests: signature mirror (python <-> rust contract), HLO
text generation, and manifest structure."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, opset


def test_conv_signature_golden():
    """Pinned against rust/src/graph/op.rs::signature — if this changes,
    rust/tests/integration_runtime.rs::signature_contract breaks too."""
    sig = opset.conv2d_signature(
        (1, 3, 32, 32), (8, 3, 3, 3), (1, 1), (1, 1), act="none", bias=True,
        extra_shapes=((8,),),
    )
    assert sig == "conv2d;st=1,1;pad=1,1;act=none;b=1;res=0;1x3x32x32;8x3x3x3;8"


def test_simple_signatures_golden():
    assert opset.simple_signature("relu", (1, 8, 32, 32)) == "relu;1x8x32x32"
    assert opset.simple_signature("matmul", (1, 16), (16, 10)) == "matmul;1x16;16x10"
    assert (
        opset.pool_signature("maxpool", (2, 2), (2, 2), (0, 0), (1, 16, 32, 32))
        == "maxpool;k=2,2;st=2,2;pad=0,0;1x16x32x32"
    )
    assert (
        opset.concat_signature([(1, 8, 32, 32), (1, 8, 32, 32)], 1)
        == "concat;ax=1;1x8x32x32;1x8x32x32"
    )


def test_conv_spec_applicability():
    c3 = opset.ConvSpec("c", (1, 8, 16, 16), (8, 8, 3, 3), (1, 1), (1, 1))
    assert "winograd" in c3.algorithms()
    c3s2 = opset.ConvSpec("c", (1, 8, 16, 16), (8, 8, 3, 3), (2, 2), (1, 1))
    assert "winograd" not in c3s2.algorithms()
    c1 = opset.ConvSpec("c", (1, 8, 16, 16), (8, 8, 1, 1), (1, 1), (0, 0))
    assert "1x1gemm" in c1.algorithms() and "winograd" not in c1.algorithms()


def test_conv_spec_out_shape():
    c = opset.ConvSpec("c", (1, 3, 32, 32), (8, 3, 3, 3), (2, 2), (1, 1))
    assert c.out_shape() == (1, 8, 16, 16)


def test_to_hlo_text_produces_parseable_module():
    fn = lambda x: (jnp.maximum(x, 0.0),)
    text = aot.to_hlo_text(fn, aot.spec_args([(2, 3)]))
    assert "HloModule" in text
    assert "ROOT" in text


def test_build_artifacts_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build_artifacts(out, batch=1, resolution=8, classes=4, verbose=False)
    assert manifest["version"] == 1
    entries = manifest["artifacts"]
    # 4 convs x (2..3 algos) + simples + 3 whole-model
    assert len(entries) >= 20
    keys = [e["key"] for e in entries]
    assert len(keys) == len(set(keys)), "artifact keys must be unique"
    assert any(k.startswith("model_fwd::") for k in keys)
    # every listed file exists and is HLO text
    for e in entries:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        with open(path) as f:
            assert "HloModule" in f.read(200)
    # manifest file on disk round-trips
    with open(os.path.join(out, "manifest.json")) as f:
        assert json.load(f) == manifest


def test_no_dense_constants_in_artifacts(tmp_path):
    """Regression guard: xla_extension 0.5.1's HLO text parser silently
    mis-parses dense (non-scalar) f32 array constants — a winograd filter
    transform built from a constant G matrix came back as zeros. No emitted
    artifact may contain a multi-element f32 constant literal."""
    import re

    out = str(tmp_path / "artifacts")
    manifest = aot.build_artifacts(out, batch=1, resolution=8, classes=4, verbose=False)
    # f32[4,3]{...} constant(...) with 2+ elements in the braces
    dense = re.compile(r"constant\(\{.*,.*\}\)")
    for e in manifest["artifacts"]:
        with open(os.path.join(out, e["file"])) as f:
            text = f.read()
        for line in text.splitlines():
            if "constant(" in line and dense.search(line):
                # allow integer/index constants; flag floating dense ones
                assert "f32[" not in line.split("=")[0], (
                    f"{e['key']}: dense f32 constant would be mis-parsed by "
                    f"xla_extension 0.5.1: {line.strip()[:120]}"
                )


def test_quickstart_opset_covers_model():
    convs, simples = opset.quickstart_opset(1, 32, 10)
    assert {c.name for c in convs} == {"stem", "branch1x1", "branch3x3", "conv2"}
    mns = {s.mnemonic for s in simples}
    assert {"relu", "maxpool", "concat", "gavgpool", "flatten", "matmul", "softmax"} <= mns
