"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth every kernel in this package is validated
against (pytest + hypothesis in python/tests/). They intentionally use
only high-level jax.numpy / lax primitives.

Layout conventions match the rust engine: NCHW activations, KCRS filters.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, bias=None, stride=(1, 1), pad=(0, 0), residual=None, relu=False):
    """Direct 2-D convolution oracle.

    x: [N, C, H, W]; w: [K, C, R, S]; bias: [K] or None.
    residual: same shape as output, added pre-activation.
    """
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if bias is not None:
        y = y + bias[None, :, None, None]
    if residual is not None:
        y = y + residual
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def dwconv2d_ref(x, w, bias=None, stride=(1, 1), pad=(0, 0), relu=False):
    """Depthwise convolution oracle: x [N,C,H,W], w [C,1,R,S]."""
    c = x.shape[1]
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    )
    if bias is not None:
        y = y + bias[None, :, None, None]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def matmul_ref(a, b):
    """[M, K] @ [K, N] oracle."""
    return jnp.matmul(a, b)


def relu_ref(x):
    return jnp.maximum(x, 0.0)


def maxpool_ref(x, k=(2, 2), stride=(2, 2), pad=(0, 0)):
    """Max pooling oracle (padding cells are -inf, never selected)."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, k[0], k[1]),
        window_strides=(1, 1, stride[0], stride[1]),
        padding=((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])),
    )


def avgpool_ref(x, k=(3, 3), stride=(1, 1), pad=(1, 1)):
    """Average pooling, divisor counts only in-bounds cells (matches the
    rust engine and cuDNN's COUNT_EXCLUDE_PADDING)."""
    ones = jnp.ones_like(x)
    window = dict(
        window_dimensions=(1, 1, k[0], k[1]),
        window_strides=(1, 1, stride[0], stride[1]),
        padding=((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])),
    )
    s = lax.reduce_window(x, 0.0, lax.add, **window)
    n = lax.reduce_window(ones, 0.0, lax.add, **window)
    return s / n


def global_avgpool_ref(x):
    return jnp.mean(x, axis=(2, 3), keepdims=True)


def softmax_ref(x):
    """Row-wise softmax over the last axis of a rank-2 tensor."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def im2col_ref(x, r, s, stride=(1, 1), pad=(0, 0)):
    """Unfold patches: [N, C, H, W] -> [N, C*R*S, OH*OW] (matches the rust
    tensor::conv::im2col layout per image)."""
    n, c, h, w = x.shape
    ph, pw = pad
    sh, sw = stride
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    oh = (h + 2 * ph - r) // sh + 1
    ow = (w + 2 * pw - s) // sw + 1
    cols = []
    for ry in range(r):
        for sx in range(s):
            patch = xp[:, :, ry : ry + oh * sh : sh, sx : sx + ow * sw : sw]
            cols.append(patch.reshape(n, c, oh * ow))
    stacked = jnp.stack(cols, axis=2)  # [N, C, R*S, OH*OW]
    return stacked.reshape(n, c * r * s, oh * ow)


def conv2d_im2col_ref(x, w, bias=None, stride=(1, 1), pad=(0, 0)):
    """Convolution via im2col + matmul (same math as conv2d_ref)."""
    n = x.shape[0]
    k, c, r, s = w.shape
    cols = im2col_ref(x, r, s, stride, pad)  # [N, C*R*S, OH*OW]
    wmat = w.reshape(k, c * r * s)
    y = jnp.einsum("kp,npq->nkq", wmat, cols)
    h, wd = x.shape[2], x.shape[3]
    oh = (h + 2 * pad[0] - r) // stride[0] + 1
    ow = (wd + 2 * pad[1] - s) // stride[1] + 1
    y = y.reshape(n, k, oh, ow)
    if bias is not None:
        y = y + bias[None, :, None, None]
    return y
