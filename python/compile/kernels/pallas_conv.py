"""Convolution algorithms as Pallas kernels — the paper's per-node
"algorithms" (cuDNN analogues) implemented for real:

- ``conv_direct``  — sliding-window accumulation (cuDNN IMPLICIT_GEMM-ish).
- ``conv_im2col``  — Pallas im2col unfold + the tiled Pallas GEMM
  (cuDNN GEMM): more memory traffic, better MXU utilization.
- ``conv_winograd``— F(2x2, 3x3) transform-space convolution (cuDNN
  WINOGRAD): 2.25x fewer multiplies; 3x3 stride-1 only.

All kernels take NCHW activations and KCRS filters and are validated
against ``ref.conv2d_ref`` by python/tests/test_kernels.py (hypothesis
sweeps shapes, strides, and padding).

TPU mapping notes (DESIGN.md §Hardware-Adaptation): the grid dimensions
(n, k) tile the output across programs so each program's working set — one
input image slab plus one filter — fits VMEM; the im2col path feeds dense
128x128 MXU tiles via pallas_matmul. interpret=True throughout (CPU PJRT
cannot execute Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_matmul import matmul as pallas_matmul


def _out_dim(h, r, s, p):
    return (h + 2 * p - r) // s + 1


def _epilogue(y, bias, residual, relu):
    if bias is not None:
        y = y + bias[None, :, None, None]
    if residual is not None:
        y = y + residual
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


# ---------------------------------------------------------------------------
# Direct convolution
# ---------------------------------------------------------------------------


def _direct_kernel(x_ref, w_ref, o_ref, *, rr, ss, sh, sw, oh, ow):
    # x_ref: [1, C, Hp, Wp] (one image, pre-padded); w_ref: [1, C, R, S]
    # (one filter); o_ref: [1, 1, OH, OW].
    x = x_ref[0]  # [C, Hp, Wp]
    w = w_ref[0]  # [C, R, S]
    acc = jnp.zeros((oh, ow), dtype=jnp.float32)
    for r in range(rr):
        for s in range(ss):
            # strided receptive-field slab for this tap: [C, OH, OW]
            slab = x[:, r : r + (oh - 1) * sh + 1 : sh, s : s + (ow - 1) * sw + 1 : sw]
            acc = acc + jnp.sum(slab * w[:, r, s][:, None, None], axis=0)
    o_ref[0, 0] = acc


def conv_direct(x, w, bias=None, stride=(1, 1), pad=(0, 0), residual=None, relu=False, interpret=True):
    """Direct convolution; grid = (N, K), one output plane per program."""
    n, c, h, wd = x.shape
    k, c2, r, s = w.shape
    assert c == c2
    sh, sw = stride
    ph, pw = pad
    oh, ow = _out_dim(h, r, sh, ph), _out_dim(wd, s, sw, pw)
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    hp, wp = h + 2 * ph, wd + 2 * pw

    kernel = functools.partial(_direct_kernel, rr=r, ss=s, sh=sh, sw=sw, oh=oh, ow=ow)
    y = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, k, oh, ow), jnp.float32),
        grid=(n, k),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda ni, ki: (ni, 0, 0, 0)),
            pl.BlockSpec((1, c, r, s), lambda ni, ki: (ki, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, oh, ow), lambda ni, ki: (ni, ki, 0, 0)),
        interpret=interpret,
    )(xp, w)
    return _epilogue(y, bias, residual, relu)


# ---------------------------------------------------------------------------
# im2col + GEMM convolution
# ---------------------------------------------------------------------------


def _im2col_kernel(x_ref, o_ref, *, c, rr, ss, sh, sw, oh, ow):
    # x_ref: [1, C, Hp, Wp]; o_ref: [1, C*R*S, OH*OW]
    x = x_ref[0]
    for r in range(rr):
        for s in range(ss):
            slab = x[:, r : r + (oh - 1) * sh + 1 : sh, s : s + (ow - 1) * sw + 1 : sw]
            # rows for tap (r, s) of every channel: row = (ci*R + r)*S + s
            row0 = r * ss + s
            o_ref[0, row0 :: rr * ss, :] = slab.reshape(c, oh * ow)


def im2col(x, r, s, stride=(1, 1), pad=(0, 0), interpret=True):
    """Pallas im2col: [N, C, H, W] -> [N, C*R*S, OH*OW]."""
    n, c, h, wd = x.shape
    sh, sw = stride
    ph, pw = pad
    oh, ow = _out_dim(h, r, sh, ph), _out_dim(wd, s, sw, pw)
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    hp, wp = h + 2 * ph, wd + 2 * pw
    kernel = functools.partial(_im2col_kernel, c=c, rr=r, ss=s, sh=sh, sw=sw, oh=oh, ow=ow)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, c * r * s, oh * ow), jnp.float32),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, c, hp, wp), lambda ni: (ni, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c * r * s, oh * ow), lambda ni: (ni, 0, 0)),
        interpret=interpret,
    )(xp)


def conv_im2col(x, w, bias=None, stride=(1, 1), pad=(0, 0), residual=None, relu=False, interpret=True):
    """im2col unfold (Pallas) + tiled GEMM (Pallas)."""
    n = x.shape[0]
    k, c, r, s = w.shape
    oh = _out_dim(x.shape[2], r, stride[0], pad[0])
    ow = _out_dim(x.shape[3], s, stride[1], pad[1])
    cols = im2col(x, r, s, stride, pad, interpret=interpret)  # [N, CRS, OHOW]
    wmat = w.reshape(k, c * r * s)
    planes = [
        pallas_matmul(wmat, cols[ni], interpret=interpret) for ni in range(n)
    ]  # each [K, OH*OW]
    y = jnp.stack(planes, axis=0).reshape(n, k, oh, ow)
    return _epilogue(y, bias, residual, relu)


# ---------------------------------------------------------------------------
# Winograd F(2x2, 3x3)
# ---------------------------------------------------------------------------


def transform_filter(w):
    """G g Gᵀ for all filters: [K, C, 3, 3] -> [K, C, 4, 4] (weight-space,
    computed once at AOT time).

    Written as unrolled scalar arithmetic instead of an einsum against a
    dense constant G: xla_extension 0.5.1's HLO *text parser* silently
    mis-parses dense f32 array constants (only scalar constants round-trip),
    so AOT-path code must never embed matrix literals. See DESIGN.md
    §Gotchas and python/tests/test_aot.py::test_no_dense_constants.
    """
    # t = G g  (rows):  [K, C, 3] each
    g0, g1, g2 = w[:, :, 0, :], w[:, :, 1, :], w[:, :, 2, :]
    trows = (g0, 0.5 * (g0 + g1 + g2), 0.5 * (g0 - g1 + g2), g2)
    # u = t Gᵀ (columns): [K, C, 4] each row
    rows = []
    for t in trows:
        a, b, c = t[..., 0], t[..., 1], t[..., 2]
        rows.append(jnp.stack([a, 0.5 * (a + b + c), 0.5 * (a - b + c), c], axis=-1))
    return jnp.stack(rows, axis=2)  # [K, C, 4, 4]


def _winograd_kernel(x_ref, uf_ref, o_ref, *, c, k, ty, tx, oh, ow):
    # x_ref: [1, C, Hp, Wp] padded so that Hp >= 2*ty + 2, Wp >= 2*tx + 2.
    # uf_ref: [K, C, 4, 4] transformed filters. o_ref: [1, K, OH2, OW2]
    # (OH2 = 2*ty, OW2 = 2*tx; wrapper slices to the true OH, OW).
    x = x_ref[0]
    uf = uf_ref[...]

    # Gather the 16 strided slabs d[dy][dx]: [C, TY, TX].
    d = [
        [x[:, dy : dy + 2 * ty : 2, dx : dx + 2 * tx : 2] for dx in range(4)]
        for dy in range(4)
    ]
    # Input transform u = Bᵀ d B (elementwise over [C, TY, TX]).
    bt0 = [d[0][j] - d[2][j] for j in range(4)]
    bt1 = [d[1][j] + d[2][j] for j in range(4)]
    bt2 = [d[2][j] - d[1][j] for j in range(4)]
    bt3 = [d[1][j] - d[3][j] for j in range(4)]
    bt = [bt0, bt1, bt2, bt3]
    u = [[None] * 4 for _ in range(4)]
    for i in range(4):
        u[i][0] = bt[i][0] - bt[i][2]
        u[i][1] = bt[i][1] + bt[i][2]
        u[i][2] = bt[i][2] - bt[i][1]
        u[i][3] = bt[i][1] - bt[i][3]

    # Elementwise multiply-accumulate over channels in transform space:
    # m[k][i][j][TY,TX] = sum_c uf[k,c,i,j] * u[i][j][c]  — einsum per (i,j).
    planes = []
    for i in range(4):
        for j in range(4):
            # [K, TY, TX] = [K, C] x [C, TY, TX]
            planes.append(jnp.einsum("kc,cyx->kyx", uf[:, :, i, j], u[i][j]))
    m = [[planes[i * 4 + j] for j in range(4)] for i in range(4)]

    # Output transform y = Aᵀ m A: [K, TY, TX] per output tap (2x2).
    at0 = [m[0][j] + m[1][j] + m[2][j] for j in range(4)]
    at1 = [m[1][j] - m[2][j] - m[3][j] for j in range(4)]
    y00 = at0[0] + at0[1] + at0[2]
    y01 = at0[1] - at0[2] - at0[3]
    y10 = at1[0] + at1[1] + at1[2]
    y11 = at1[1] - at1[2] - at1[3]

    # Interleave 2x2 taps back into [K, 2*TY, 2*TX].
    top = jnp.stack([y00, y01], axis=-1).reshape(k, ty, 2 * tx)
    bot = jnp.stack([y10, y11], axis=-1).reshape(k, ty, 2 * tx)
    out = jnp.stack([top, bot], axis=2).reshape(k, 2 * ty, 2 * tx)
    o_ref[0] = out


def conv_winograd(x, w, bias=None, pad=(1, 1), residual=None, relu=False, interpret=True):
    """Winograd F(2x2,3x3); requires 3x3 filters, stride 1."""
    n, c, h, wd = x.shape
    k, c2, r, s = w.shape
    assert (r, s) == (3, 3), "winograd requires 3x3"
    assert c == c2
    ph, pw = pad
    oh, ow = _out_dim(h, 3, 1, ph), _out_dim(wd, 3, 1, pw)
    ty, tx = (oh + 1) // 2, (ow + 1) // 2
    # Pad so every 4x4 input tile is in-bounds: need 2*ty + 2 rows.
    hp_need, wp_need = 2 * ty + 2, 2 * tx + 2
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (0, 0),
            (ph, max(0, hp_need - h - ph)),
            (pw, max(0, wp_need - wd - pw)),
        ),
    )
    uf = transform_filter(w)
    kernel = functools.partial(_winograd_kernel, c=c, k=k, ty=ty, tx=tx, oh=oh, ow=ow)
    y = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, k, 2 * ty, 2 * tx), jnp.float32),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, c, xp.shape[2], xp.shape[3]), lambda ni: (ni, 0, 0, 0)),
            pl.BlockSpec((k, c, 4, 4), lambda ni: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, 2 * ty, 2 * tx), lambda ni: (ni, 0, 0, 0)),
        interpret=interpret,
    )(xp, uf)
    y = y[:, :, :oh, :ow]
    return _epilogue(y, bias, residual, relu)


# ---------------------------------------------------------------------------
# Depthwise convolution
# ---------------------------------------------------------------------------


def _dw_kernel(x_ref, w_ref, o_ref, *, rr, ss, sh, sw, oh, ow):
    # x_ref: [1, 1, Hp, Wp] (one image, one channel, pre-padded);
    # w_ref: [1, 1, R, S]; o_ref: [1, 1, OH, OW].
    x = x_ref[0, 0]
    w = w_ref[0, 0]
    acc = jnp.zeros((oh, ow), dtype=jnp.float32)
    for r in range(rr):
        for s in range(ss):
            slab = x[r : r + (oh - 1) * sh + 1 : sh, s : s + (ow - 1) * sw + 1 : sw]
            acc = acc + slab * w[r, s]
    o_ref[0, 0] = acc


def dwconv_direct(x, w, bias=None, stride=(1, 1), pad=(0, 0), relu=False, interpret=True):
    """Depthwise conv as a Pallas kernel; grid = (N, C), one plane per
    program (each channel is independent — the MobileNet hot spot)."""
    n, c, h, wd = x.shape
    wc, mult, r, s = w.shape
    assert wc == c and mult == 1, "depthwise weight must be [C,1,R,S]"
    sh, sw = stride
    ph, pw = pad
    oh, ow = _out_dim(h, r, sh, ph), _out_dim(wd, s, sw, pw)
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    hp, wp = h + 2 * ph, wd + 2 * pw
    kernel = functools.partial(_dw_kernel, rr=r, ss=s, sh=sh, sw=sw, oh=oh, ow=ow)
    y = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, c, oh, ow), jnp.float32),
        grid=(n, c),
        in_specs=[
            pl.BlockSpec((1, 1, hp, wp), lambda ni, ci: (ni, ci, 0, 0)),
            pl.BlockSpec((1, 1, r, s), lambda ni, ci: (ci, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, oh, ow), lambda ni, ci: (ni, ci, 0, 0)),
        interpret=interpret,
    )(xp, w)
    if bias is not None:
        y = y + bias[None, :, None, None]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y
