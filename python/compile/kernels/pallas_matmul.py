"""Tiled matmul as a Pallas kernel (the GEMM that backs `gemm_blocked` and
the im2col convolution's contraction).

TPU-idiomatic structure: the grid walks (M/tm, N/tn, K/tk) tiles, each
program multiplies one (tm x tk) x (tk x tn) pair on the MXU and
accumulates into the output tile resident in VMEM. Default tiles are
128x128 (the MXU systolic array edge); callers with smaller operands get
clipped tiles.

Lowered with interpret=True — real-TPU Mosaic lowering is compile-only on
this host (see DESIGN.md §Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_div(a, b):
    return (a + b - 1) // b


def matmul(a, b, tile_m=128, tile_n=128, tile_k=128, interpret=True):
    """C[M,N] = A[M,K] @ B[K,N] via a tiled Pallas kernel.

    Operands with dimensions that are not tile multiples are zero-padded to
    the tile grid and the result sliced back — the standard TPU approach
    (pad once in HBM, keep the MXU tiles dense).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul inner dim mismatch {k} vs {k2}"
    tm = min(tile_m, m)
    tn = min(tile_n, n)
    tk = min(tile_k, k)
    mp, np_, kp = _ceil_div(m, tm) * tm, _ceil_div(n, tn) * tn, _ceil_div(k, tk) * tk
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // tm, np_ // tn, kp // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
