"""L2: the quickstart CNN forward pass in JAX, composed from the L1 Pallas
kernels. Lowered once by aot.py into a single whole-model artifact
(``model_fwd``) whose weights are *call arguments* — the rust side feeds
its deterministically-realized weights at execution time, so no RNG scheme
needs to be shared across languages.

Architecture (mirrors rust/src/models/simple.rs::build_cnn):
    stem conv3x3(3->8)+relu
    -> [branch 1x1(8->8)+relu || branch 3x3(8->8)+relu] -> concat
    -> maxpool2x2 -> conv3x3(16->16)+relu -> GAP -> FC -> softmax
"""

import jax.numpy as jnp

from .kernels import ref
from .kernels.pallas_conv import conv_direct, conv_im2col, conv_winograd
from .kernels.pallas_matmul import matmul as pallas_matmul

#: (name, shape) of every weight, in call order after the input tensor.
WEIGHT_SPECS = [
    ("stem_w", (8, 3, 3, 3)),
    ("stem_b", (8,)),
    ("branch1x1_w", (8, 8, 1, 1)),
    ("branch1x1_b", (8,)),
    ("branch3x3_w", (8, 8, 3, 3)),
    ("branch3x3_b", (8,)),
    ("conv2_w", (16, 16, 3, 3)),
    ("conv2_b", (16,)),
    ("fc_w", (16, 10)),
]


def conv_by_algo(algo, x, w, bias, stride, pad):
    """Dispatch to the Pallas kernel implementing `algo` (paper §3.1:
    the algorithm assignment decides which implementation runs)."""
    if algo == "direct":
        return conv_direct(x, w, bias=bias, stride=stride, pad=pad)
    if algo == "im2col":
        return conv_im2col(x, w, bias=bias, stride=stride, pad=pad)
    if algo == "winograd":
        assert stride == (1, 1) and w.shape[2:] == (3, 3)
        return conv_winograd(x, w, bias=bias, pad=pad)
    if algo == "1x1gemm":
        assert w.shape[2:] == (1, 1) and pad == (0, 0)
        n, c, h, wd = x.shape
        k = w.shape[0]
        if stride != (1, 1):
            x = x[:, :, :: stride[0], :: stride[1]]
            n, c, h, wd = x.shape
        wmat = w.reshape(k, c)
        planes = [pallas_matmul(wmat, x[ni].reshape(c, h * wd)) for ni in range(n)]
        y = jnp.stack(planes, axis=0).reshape(n, k, h, wd)
        return y + bias[None, :, None, None] if bias is not None else y
    raise ValueError(f"unknown conv algorithm {algo}")


def forward(x, *weights, algo="im2col"):
    """Quickstart CNN forward. `algo` selects the convolution kernel used
    for every conv (the whole-model artifact is built per algorithm)."""
    (stem_w, stem_b, b1_w, b1_b, b3_w, b3_b, c2_w, c2_b, fc_w) = weights
    # For non-universally-applicable algorithms fall back per node the same
    # way the rust registry would (winograd only on 3x3 s1; 1x1gemm on 1x1).
    def conv(x, w, b, stride, pad):
        a = algo
        r, s = w.shape[2], w.shape[3]
        if a == "winograd" and not ((r, s) == (3, 3) and stride == (1, 1)):
            a = "im2col"
        if a == "1x1gemm" and not ((r, s) == (1, 1) and pad == (0, 0)):
            a = "im2col"
        return conv_by_algo(a, x, w, b, stride, pad)

    y = ref.relu_ref(conv(x, stem_w, stem_b, (1, 1), (1, 1)))
    e1 = ref.relu_ref(conv(y, b1_w, b1_b, (1, 1), (0, 0)))
    e3 = ref.relu_ref(conv(y, b3_w, b3_b, (1, 1), (1, 1)))
    cat = jnp.concatenate([e1, e3], axis=1)
    p = ref.maxpool_ref(cat, (2, 2), (2, 2), (0, 0))
    c2 = ref.relu_ref(conv(p, c2_w, c2_b, (1, 1), (1, 1)))
    gap = ref.global_avgpool_ref(c2)
    flat = gap.reshape(gap.shape[0], -1)
    logits = pallas_matmul(flat, fc_w)
    return ref.softmax_ref(logits)


def forward_ref(x, *weights):
    """Same network through the pure-jnp oracles only (pytest ground truth)."""
    (stem_w, stem_b, b1_w, b1_b, b3_w, b3_b, c2_w, c2_b, fc_w) = weights
    y = ref.conv2d_ref(x, stem_w, stem_b, (1, 1), (1, 1), relu=True)
    e1 = ref.conv2d_ref(y, b1_w, b1_b, (1, 1), (0, 0), relu=True)
    e3 = ref.conv2d_ref(y, b3_w, b3_b, (1, 1), (1, 1), relu=True)
    cat = jnp.concatenate([e1, e3], axis=1)
    p = ref.maxpool_ref(cat, (2, 2), (2, 2), (0, 0))
    c2 = ref.conv2d_ref(p, c2_w, c2_b, (1, 1), (1, 1), relu=True)
    gap = ref.global_avgpool_ref(c2)
    flat = gap.reshape(gap.shape[0], -1)
    return ref.softmax_ref(ref.matmul_ref(flat, fc_w))
