"""The AOT operator set: which (node signature, algorithm) artifacts to
build, keyed exactly the way the rust engine looks them up.

``signature()`` mirrors ``rust/src/graph/op.rs::OpKind::signature`` — the
two must stay in lock-step (python/tests/test_opset.py pins golden strings
that the rust side pins too, in rust/tests/integration_runtime.rs).
"""

from dataclasses import dataclass, field


def _shape_str(shape):
    return "x".join(str(d) for d in shape)


def conv2d_signature(x_shape, w_shape, stride, pad, act="none", bias=False, residual=False, extra_shapes=()):
    """Mirror of OpKind::Conv2d signature()."""
    parts = [
        "conv2d",
        f"st={stride[0]},{stride[1]}",
        f"pad={pad[0]},{pad[1]}",
        f"act={act}",
        f"b={int(bias)}",
        f"res={int(residual)}",
        _shape_str(x_shape),
        _shape_str(w_shape),
    ]
    parts.extend(_shape_str(s) for s in extra_shapes)
    return ";".join(parts)


def simple_signature(mnemonic, *shapes):
    """Mirror of the attribute-free ops (relu, matmul, gavgpool, ...)."""
    return ";".join([mnemonic] + [_shape_str(s) for s in shapes])


def pool_signature(mnemonic, k, stride, pad, x_shape):
    return ";".join(
        [
            mnemonic,
            f"k={k[0]},{k[1]}",
            f"st={stride[0]},{stride[1]}",
            f"pad={pad[0]},{pad[1]}",
            _shape_str(x_shape),
        ]
    )


@dataclass
class ConvSpec:
    """One convolution configuration to compile, under every applicable
    algorithm (the applicability rules mirror rust/src/algo)."""

    name: str
    x_shape: tuple
    w_shape: tuple
    stride: tuple = (1, 1)
    pad: tuple = (0, 0)
    bias: bool = True
    act: str = "none"

    def algorithms(self):
        r, s = self.w_shape[2], self.w_shape[3]
        algos = ["im2col", "direct"]
        if (r, s) == (3, 3) and self.stride == (1, 1):
            algos.append("winograd")
        if (r, s) == (1, 1) and self.pad == (0, 0):
            algos.append("1x1gemm")
        return algos

    def signature(self):
        extra = ((self.w_shape[0],),) if self.bias else ()
        return conv2d_signature(
            self.x_shape,
            self.w_shape,
            self.stride,
            self.pad,
            act=self.act,
            bias=self.bias,
            extra_shapes=extra,
        )

    def out_shape(self):
        n, c, h, w = self.x_shape
        k, _, r, s = self.w_shape
        oh = (h + 2 * self.pad[0] - r) // self.stride[0] + 1
        ow = (w + 2 * self.pad[1] - s) // self.stride[1] + 1
        return (n, k, oh, ow)


@dataclass
class SimpleSpec:
    """An attribute-light op compiled from plain jnp (kernel='jnp')."""

    name: str
    mnemonic: str
    in_shapes: tuple
    out_shapes: tuple
    attrs: dict = field(default_factory=dict)

    def signature(self):
        if self.mnemonic in ("maxpool", "avgpool"):
            return pool_signature(
                self.mnemonic,
                self.attrs["k"],
                self.attrs["stride"],
                self.attrs["pad"],
                self.in_shapes[0],
            )
        if self.mnemonic == "concat":
            return concat_signature(self.in_shapes, self.attrs.get("axis", 1))
        return simple_signature(self.mnemonic, *self.in_shapes)

    def algorithms(self):
        """Algorithm names this artifact serves (mirrors rust/src/algo)."""
        if self.mnemonic == "matmul":
            return ["gemm_blocked", "gemm_naive"]
        return ["std"]


def quickstart_opset(batch=1, resolution=32, classes=10):
    """The operator suite of models::simple::build_cnn at its default scale:
    every runtime node signature of the quickstart CNN, so the PJRT engine
    can execute the whole model from artifacts."""
    n, r = batch, resolution
    r2 = r // 2
    convs = [
        ConvSpec("stem", (n, 3, r, r), (8, 3, 3, 3), (1, 1), (1, 1)),
        ConvSpec("branch1x1", (n, 8, r, r), (8, 8, 1, 1), (1, 1), (0, 0)),
        ConvSpec("branch3x3", (n, 8, r, r), (8, 8, 3, 3), (1, 1), (1, 1)),
        ConvSpec("conv2", (n, 16, r2, r2), (16, 16, 3, 3), (1, 1), (1, 1)),
    ]
    simples = [
        SimpleSpec("relu_8", "relu", ((n, 8, r, r),), ((n, 8, r, r),)),
        SimpleSpec("relu_16", "relu", ((n, 16, r2, r2),), ((n, 16, r2, r2),)),
        SimpleSpec(
            "pool",
            "maxpool",
            ((n, 16, r, r),),
            ((n, 16, r2, r2),),
            {"k": (2, 2), "stride": (2, 2), "pad": (0, 0)},
        ),
        SimpleSpec("concat", "concat", ((n, 8, r, r), (n, 8, r, r)), ((n, 16, r, r),), {"axis": 1}),
        SimpleSpec("gap", "gavgpool", ((n, 16, r2, r2),), ((n, 16, 1, 1),)),
        SimpleSpec("flatten", "flatten", ((n, 16, 1, 1),), ((n, 16),)),
        SimpleSpec("fc", "matmul", ((n, 16), (16, classes)), ((n, classes),)),
        SimpleSpec("softmax", "softmax", ((n, classes),), ((n, classes),)),
    ]
    return convs, simples


# Concat's signature includes the axis attribute; mirror it exactly.
def concat_signature(shapes, axis=1):
    return ";".join([f"concat;ax={axis}"] + [_shape_str(s) for s in shapes])
