"""AOT compiler: lowers the L1/L2 JAX+Pallas computations to HLO **text**
artifacts + manifest.json, consumed by the rust PJRT runtime.

Run once via ``make artifacts``; never imported at inference time.

Interchange format is HLO text, NOT ``lowered.compile()`` /
``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, opset
from .kernels import ref
from .kernels.pallas_conv import conv_direct, conv_im2col, conv_winograd
from .kernels.pallas_matmul import matmul as pallas_matmul


def to_hlo_text(fn, example_args):
    """Lower a jax-jittable fn to HLO text (return_tuple=True: the rust
    side always untuples)."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_args(shapes):
    return [jax.ShapeDtypeStruct(tuple(s), jnp.float32) for s in shapes]


def conv_fn(algo, spec: opset.ConvSpec):
    stride, pad, bias = spec.stride, spec.pad, spec.bias

    def fn(x, w, *rest):
        b = rest[0] if bias else None
        if algo == "direct":
            y = conv_direct(x, w, bias=b, stride=stride, pad=pad)
        elif algo == "im2col":
            y = conv_im2col(x, w, bias=b, stride=stride, pad=pad)
        elif algo == "winograd":
            y = conv_winograd(x, w, bias=b, pad=pad)
        elif algo == "1x1gemm":
            y = model.conv_by_algo("1x1gemm", x, w, b, stride, pad)
        else:
            raise ValueError(algo)
        return (y,)

    return fn


def simple_fn(spec: opset.SimpleSpec, algo: str):
    m = spec.mnemonic
    if m == "relu":
        return lambda x: (ref.relu_ref(x),)
    if m == "maxpool":
        k, st, pd = spec.attrs["k"], spec.attrs["stride"], spec.attrs["pad"]
        return lambda x: (ref.maxpool_ref(x, k, st, pd),)
    if m == "avgpool":
        k, st, pd = spec.attrs["k"], spec.attrs["stride"], spec.attrs["pad"]
        return lambda x: (ref.avgpool_ref(x, k, st, pd),)
    if m == "concat":
        axis = spec.attrs.get("axis", 1)
        return lambda *xs: (jnp.concatenate(xs, axis=axis),)
    if m == "gavgpool":
        return lambda x: (ref.global_avgpool_ref(x),)
    if m == "flatten":
        return lambda x: (x.reshape(x.shape[0], -1),)
    if m == "matmul":
        if algo == "gemm_blocked":
            return lambda a, b: (pallas_matmul(a, b),)
        return lambda a, b: (ref.matmul_ref(a, b),)
    if m == "softmax":
        return lambda x: (ref.softmax_ref(x),)
    raise ValueError(f"no lowering for {m}")


def build_artifacts(out_dir, batch=1, resolution=32, classes=10, verbose=True):
    """Build the full artifact suite; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    counter = 0

    def emit(key, fn, in_shapes, out_shapes, kernel):
        nonlocal counter
        fname = f"k{counter:03d}.hlo.txt"
        counter += 1
        text = to_hlo_text(fn, spec_args(in_shapes))
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "key": key,
                "file": fname,
                "inputs": [list(s) for s in in_shapes],
                "outputs": [list(s) for s in out_shapes],
                "kernel": kernel,
            }
        )
        if verbose:
            print(f"  {fname}  {key}")

    convs, simples = opset.quickstart_opset(batch, resolution, classes)
    for spec in convs:
        sig = spec.signature()
        in_shapes = [spec.x_shape, spec.w_shape] + ([(spec.w_shape[0],)] if spec.bias else [])
        for algo in spec.algorithms():
            emit(
                f"{sig}::{algo}",
                conv_fn(algo, spec),
                in_shapes,
                [spec.out_shape()],
                f"pallas_{algo}",
            )
    for spec in simples:
        sig = spec.signature()
        for algo in spec.algorithms():
            kernel = "pallas_matmul" if (spec.mnemonic, algo) == ("matmul", "gemm_blocked") else "jnp"
            emit(f"{sig}::{algo}", simple_fn(spec, algo), spec.in_shapes, spec.out_shapes, kernel)

    # Whole-model artifacts, one per conv algorithm (the L2 deliverable).
    x_shape = (batch, 3, resolution, resolution)
    w_shapes = [s for (_, s) in model.WEIGHT_SPECS]
    for algo in ["im2col", "direct", "winograd"]:
        fn = lambda x, *w, _a=algo: (model.forward(x, *w, algo=_a),)
        emit(
            f"model_fwd::{algo}",
            fn,
            [x_shape] + w_shapes,
            [(batch, classes)],
            f"pallas_{algo}+jnp",
        )

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--resolution", type=int, default=32)
    ap.add_argument("--classes", type=int, default=10)
    args = ap.parse_args()
    out_dir = args.out
    # `--out path/model.hlo.txt` (legacy Makefile target) -> use its dir
    if out_dir.endswith(".hlo.txt"):
        out_dir = os.path.dirname(out_dir) or "."
    manifest = build_artifacts(out_dir, args.batch, args.resolution, args.classes)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
